"""Benchmark driver — one section per paper table/figure.
Prints ``name,us_per_call,derived`` CSV (paper Fig. 7, Fig. 8, Fig. 9,
Appendix D, Appendix E.1), then the roofline summary pointer, and
writes a machine-readable ``BENCH_<timestamp>.json`` next to the CSV
output so the perf trajectory is trackable across PRs.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--skip-skew]
    PYTHONPATH=src python -m benchmarks.run --trajectory   # summarize
"""
import argparse
import glob
import json
import os
import sys
import time
import traceback

from benchmarks import common


def trajectory(out_dir: str) -> None:
    """Summarize the BENCH_<timestamp>.json series already on disk:
    one line per (section, benchmark) with its us_per_call across
    runs, oldest -> newest, so cross-PR drift is visible at a
    glance."""
    paths = sorted(glob.glob(os.path.join(out_dir, "BENCH_*.json")))
    if not paths:
        print(f"# no BENCH_*.json under {out_dir}")
        return
    runs = []
    for p in paths:
        try:
            with open(p) as f:
                runs.append(json.load(f))
        except (OSError, ValueError):
            print(f"# skipping unreadable {p}")
    stamps = [r.get("timestamp", "?") for r in runs]
    print(f"# {len(runs)} runs: {stamps[0]} .. {stamps[-1]}")
    series = {}        # (section, name) -> [us or None per run]
    for i, r in enumerate(runs):
        for sec, names in r.get("sections", {}).items():
            for name, rec in names.items():
                series.setdefault((sec, name),
                                  [None] * len(runs))[i] = rec
    print("section,name,us_per_call_series,"
          "p50_ms_series,p95_ms_series,p99_ms_series,latest_extras")
    for (sec, name), recs in sorted(series.items()):
        us = ["-" if rec is None else f"{rec.get('us_per_call', 0):g}"
              for rec in recs]
        last = next(rec for rec in reversed(recs) if rec is not None)

        def pseries(key):
            # latency-percentile drift, same oldest->newest shape as
            # us_per_call; benchmarks that don't emit them show "-"
            vals = ["-" if rec is None or key not in rec
                    else f"{rec[key]:g}" for rec in recs]
            return "->".join(vals) if any(v != "-" for v in vals) \
                else "-"
        extras = ";".join(f"{k}={v}" for k, v in sorted(last.items())
                          if k not in ("us_per_call", "derived",
                                       "p50_ms", "p95_ms", "p99_ms"))
        print(f"{sec},{name},{'->'.join(us)},{pseries('p50_ms')},"
              f"{pseries('p95_ms')},{pseries('p99_ms')},{extras}")
    failed = [(r.get("timestamp"), r.get("failed_sections"))
              for r in runs if r.get("failed_sections")]
    if failed:
        print(f"# runs with failed sections: {failed}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--skip-skew", action="store_true",
                    help="skip the 8-virtual-device subprocess benchmark")
    ap.add_argument("--out-dir", default=".",
                    help="directory for BENCH_<timestamp>.json")
    ap.add_argument("--trajectory", action="store_true",
                    help="don't run anything: summarize the existing "
                         "BENCH_*.json series in --out-dir")
    args = ap.parse_args()
    if args.trajectory:
        trajectory(args.out_dir)
        return
    # fail fast on an unwritable destination, not after the full run
    os.makedirs(args.out_dir, exist_ok=True)

    print("name,us_per_call,derived")
    sections = []
    from benchmarks import (biomedical, fused_pipeline, representation,
                            serving, storage, succinct, tpch_nested)
    sections.append(("tpch_nested (Fig.7)",
                     lambda: tpch_nested.run(scale=30 if args.quick else 60)))
    sections.append(("serving (plan-cache query service)",
                     lambda: serving.run(
                         n_orders=300 if args.quick else 2000,
                         invocations=20 if args.quick else 50)))
    sections.append(("fused_pipeline (order-aware executor)",
                     lambda: fused_pipeline.run(
                         n=5000 if args.quick else 20000,
                         dist_n=2000 if args.quick else 4000)))
    def storage_section():
        storage.run(n_orders=300 if args.quick else 2000,
                    n_parts=128 if args.quick else 512,
                    chunk_rows=32 if args.quick else 64)
        # compression ratio / decode GB/s / morsel-stream records ride
        # in the same trajectory file
        if args.quick:
            storage.run_compression(n_orders=1200, fanout=40,
                                    chunk_rows=8192, iters=3,
                                    smoke=True)
            storage.run_streamed(n_orders=400, n_parts=128,
                                 chunk_rows=32)
        else:
            storage.run_compression()
            storage.run_streamed()
    sections.append(("storage (persisted shredded datasets)",
                     storage_section))
    sections.append(("biomedical E2E (Fig.9)",
                     lambda: biomedical.run(n_samples=6 if args.quick else 10)))
    sections.append(("succinct (App.D)", succinct.run))
    sections.append(("representation (App.E.1)",
                     lambda: representation.run(
                         n=5000 if args.quick else 20000)))
    if not args.skip_skew:
        from benchmarks import cost, hypercube, skew
        sections.append(("skew (Fig.8)", skew.run))
        sections.append(("hypercube (one-round multiway join)",
                         lambda: hypercube.run(smoke=args.quick)))
        sections.append(("cost (cost-based optimizer)",
                         lambda: cost.run(smoke=args.quick)))

    failed = []
    for name, fn in sections:
        print(f"# --- {name} ---", flush=True)
        common.set_section(name)
        try:
            fn()
        except Exception:
            traceback.print_exc()
            failed.append(name)
        finally:
            common.set_section(None)
    print("# --- roofline (assignment) ---")
    print("# see: PYTHONPATH=src python -m benchmarks.roofline")

    stamp = time.strftime("%Y%m%d_%H%M%S")
    by_section = {}
    for rec in common.RECORDS:
        # keep every emitted field (us_per_call, derived, and the
        # compile_ms/warm_ms split) in the perf-trajectory file
        payload_rec = {k: v for k, v in rec.items()
                       if k not in ("section", "name")}
        by_section.setdefault(rec["section"] or "unsectioned", {})[
            rec["name"]] = payload_rec
    payload = {"timestamp": stamp, "quick": args.quick,
               "failed_sections": failed, "sections": by_section}
    out_path = f"{args.out_dir}/BENCH_{stamp}.json"
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {out_path}")

    if failed:
        print(f"# FAILED sections: {failed}")
        sys.exit(1)
    print("# all benchmark sections completed")


if __name__ == '__main__':
    main()
