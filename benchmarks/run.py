"""Benchmark driver — one section per paper table/figure.
Prints ``name,us_per_call,derived`` CSV (paper Fig. 7, Fig. 8, Fig. 9,
Appendix D, Appendix E.1), then the roofline summary pointer.

    PYTHONPATH=src python -m benchmarks.run [--quick]
"""
import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--skip-skew", action="store_true",
                    help="skip the 8-virtual-device subprocess benchmark")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    sections = []
    from benchmarks import biomedical, representation, succinct, tpch_nested
    sections.append(("tpch_nested (Fig.7)",
                     lambda: tpch_nested.run(scale=30 if args.quick else 60)))
    sections.append(("biomedical E2E (Fig.9)",
                     lambda: biomedical.run(n_samples=6 if args.quick else 10)))
    sections.append(("succinct (App.D)", succinct.run))
    sections.append(("representation (App.E.1)",
                     lambda: representation.run(
                         n=5000 if args.quick else 20000)))
    if not args.skip_skew:
        from benchmarks import skew
        sections.append(("skew (Fig.8)", skew.run))

    failed = []
    for name, fn in sections:
        print(f"# --- {name} ---", flush=True)
        try:
            fn()
        except Exception:
            traceback.print_exc()
            failed.append(name)
    print("# --- roofline (assignment) ---")
    print("# see: PYTHONPATH=src python -m benchmarks.roofline")
    if failed:
        print(f"# FAILED sections: {failed}")
        sys.exit(1)
    print("# all benchmark sections completed")


if __name__ == '__main__':
    main()
