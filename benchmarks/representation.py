"""Representation benchmark (paper Appendix E.1 analogue): the paper
compares Spark RDDs-of-case-classes vs Datasets (binary columnar). Our
twin comparison: row-at-a-time Python dict processing (AoS) vs the
columnar FlatBag engine (SoA), and the Pallas segment-reduce vs the jnp
fallback for the Gamma+ hot spot."""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.columnar.table import FlatBag
from repro.exec import ops as X
from .common import emit, time_fn


def run(n: int = 20000, groups: int = 256):
    rng = np.random.RandomState(0)
    rows = [{"k": int(rng.randint(0, groups)), "v": float(rng.rand())}
            for _ in range(n)]

    # AoS: row-at-a-time dict aggregation (the RDD analogue)
    def aos():
        acc = {}
        for r in rows:
            acc[r["k"]] = acc.get(r["k"], 0.0) + r["v"]
        return acc

    us_aos = time_fn(aos, warmup=0, iters=3)
    emit("repr_rowwise_sumby", us_aos, f"n={n}")

    # SoA: columnar sum_by (jit)
    bag = FlatBag.from_rows(rows, {"k": "int", "v": "real"})
    f = jax.jit(lambda b: X.sum_by(b, ("k",), ("v",)))
    us_soa = time_fn(lambda: f(bag))
    emit("repr_columnar_sumby", us_soa, f"speedup=x{us_aos/us_soa:.1f}")

    # Gamma+ kernel path: Pallas segment_reduce (interpret) vs jnp
    seg = np.sort(rng.randint(0, groups, n)).astype(np.int32)
    vals = rng.rand(n, 1).astype(np.float32)
    from repro.kernels import ops as K
    from repro.kernels import ref as R
    us_ref = time_fn(lambda: R.segment_reduce_ref(
        jnp.asarray(vals), jnp.asarray(seg), groups))
    emit("repr_segment_reduce_jnp", us_ref, "")
    got = K.segment_reduce(jnp.asarray(vals), jnp.asarray(seg), groups)
    want = R.segment_reduce_ref(jnp.asarray(vals), jnp.asarray(seg), groups)
    ok = bool(jnp.allclose(got, want, atol=1e-3))
    emit("repr_segment_reduce_pallas_interp_matches", 0.0, str(ok))
    assert ok


if __name__ == "__main__":
    run()
