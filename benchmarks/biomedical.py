"""Biomedical E2E pipeline (paper Fig. 9 / Appendix C): 5-step driver
gene analysis as a sequence of NRC queries over the shredded engine —
the output of each step feeds the next WITHOUT unshredding."""

from __future__ import annotations

from repro.core import codegen as CG
from repro.core import interpreter as I
from repro.core import materialization as M
from repro.core import nrc as N
from repro.core.plans import ExecSettings
from repro.core.unnesting import Catalog
from repro.data.generators import BIO_TYPES, gen_biomedical

from .common import emit, time_fn

CATALOG = Catalog(unique_keys={
    "SOImpact__F": ("conseq",), "Biomart__F": ("protein",),
    "Samples__F": ("sample",)})


def build_pipeline() -> N.Program:
    Occ = N.Var("Occurrences", BIO_TYPES["Occurrences"])
    CN = N.Var("CopyNumber", BIO_TYPES["CopyNumber"])
    Sam = N.Var("Samples", BIO_TYPES["Samples"])
    SO = N.Var("SOImpact", BIO_TYPES["SOImpact"])
    Net = N.Var("Network", BIO_TYPES["Network"])
    Bio = N.Var("Biomart", BIO_TYPES["Biomart"])
    Expr = N.Var("GeneExpression", BIO_TYPES["GeneExpression"])

    # Step 1: hybrid scores — flatten Occurrences, join CopyNumber at the
    # candidate level and SOImpact at the consequence level, aggregate
    # per (sample, gene). (§C.2.1, simplified impact formula)
    def scores_q(s):
        inner = N.for_in("o", Occ, lambda o:
            N.IfThen(o.sample.eq(s.sample),
                N.for_in("t", o.candidates, lambda t:
                    N.for_in("n", CN, lambda n:
                        N.IfThen(N.BoolOp("&&", s.aliquot.eq(n.aliquot),
                                          n.gene.eq(t.gene)),
                            N.for_in("c", t.consequences, lambda c:
                                N.for_in("v", SO, lambda v:
                                    N.IfThen(c.conseq.eq(v.conseq),
                                        N.Singleton(N.record(
                                            gene=t.gene,
                                            score=t.impact * v.value
                                            * t.sift * t.poly))))))))))
        return N.SumBy(inner, keys=("gene",), values=("score",))

    hybrid = N.for_in("s", Sam, lambda s: N.Singleton(N.record(
        sample=s.sample, aliquot=s.aliquot, scores=scores_q(s))))

    # Step 2: by-sample network effect (join hybrid scores into edges)
    HM = N.Var("HybridMatrix", hybrid.ty)

    def nodes_q(h):
        inner = N.for_in("n", Net, lambda n:
            N.for_in("e", n.edges, lambda e:
                N.for_in("b", Bio, lambda b:
                    N.IfThen(e.edgeProtein.eq(b.protein),
                        N.for_in("y", h.scores, lambda y:
                            N.IfThen(y.gene.eq(b.gene),
                                N.Singleton(N.record(
                                    node=n.nodeProtein,
                                    score=y.score))))))))
        return N.SumBy(inner, keys=("node",), values=("score",))

    sample_net = N.for_in("h", HM, lambda h: N.Singleton(N.record(
        sample=h.sample, aliquot=h.aliquot, nodes=nodes_q(h))))

    # Step 3+4: connection scores (effect x expression), per sample
    SN = N.Var("SampleNetwork", sample_net.ty)

    def conn_q(sn):
        inner = N.for_in("nd", sn.nodes, lambda nd:
            N.for_in("b", Bio, lambda b:
                N.IfThen(nd.node.eq(b.protein),
                    N.for_in("g", Expr, lambda g:
                        N.IfThen(N.BoolOp("&&", g.gene.eq(b.gene),
                                          g.aliquot.eq(sn.aliquot)),
                            N.Singleton(N.record(
                                gene=g.gene,
                                score=nd.score * g.fpkm)))))))
        return N.SumBy(inner, keys=("gene",), values=("score",))

    connect = N.for_in("sn", SN, lambda sn: N.Singleton(N.record(
        sample=sn.sample, scores=conn_q(sn))))

    # Step 5: gene connectivity across all samples (flat output)
    CM = N.Var("ConnectMatrix", connect.ty)
    connectivity = N.SumBy(
        N.for_in("s", CM, lambda s:
            N.for_in("c", s.scores, lambda c:
                N.Singleton(N.record(gene=c.gene, score=c.score)))),
        keys=("gene",), values=("score",))

    return N.Program([
        N.Assignment("HybridMatrix", hybrid),
        N.Assignment("SampleNetwork", sample_net),
        N.Assignment("ConnectMatrix", connect),
        N.Assignment("Connectivity", connectivity),
    ])


def run(n_samples: int = 10, n_genes: int = 30):
    db = gen_biomedical(n_samples=n_samples, n_genes=n_genes, seed=0)
    prog = build_pipeline()

    # oracle (direct nested evaluation of the whole pipeline)
    direct_env = I.eval_program(prog, dict(db))
    want = direct_env["Connectivity"]

    # shredded engine: whole pipeline over dictionaries, no unshredding
    sp = M.shred_program(prog, BIO_TYPES, domain_elimination=True)
    cp = CG.compile_program(sp, CATALOG)
    env0 = CG.columnar_shred_inputs(db, BIO_TYPES)
    us = time_fn(lambda: CG.run_flat_program(cp, env0))
    env = CG.run_flat_program(cp, env0)
    man = sp.manifests["Connectivity"]
    got = env[man.top].to_rows()
    ok = I.bags_equal(want, got)
    assert ok, "E2E pipeline mismatch vs oracle"
    emit("bio_e2e_shred", us,
         f"steps=4;assignments={len(sp.program.names())};match={ok}")

    # interpreter route for scale reference
    us_interp = time_fn(
        lambda: I.eval_program(prog, dict(db))["Connectivity"],
        warmup=0, iters=1)
    emit("bio_e2e_interpreter", us_interp, "")


if __name__ == "__main__":
    run()
