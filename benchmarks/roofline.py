"""Roofline analysis from the dry-run artifacts (assignment §Roofline).

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.

Terms (seconds, per step, per chip — the SPMD HLO is per-device):
  compute    = dot_flops / peak_flops          (trip-count-scaled dots)
  memory     = hbm_bytes / hbm_bw              (see note below)
  collective = collective_bytes / link_bw      (trip-count-scaled)

HBM bytes: we report two bounds and use their geometric mean as the
term — ``cost_analysis['bytes accessed']`` counts rolled loops once
(lower bound), ``scaled.hbm_bytes_proxy`` counts every instruction
result x2 (upper bound; fusion internals excluded). MODEL_FLOPS uses
6*N_active*D (train) / 2*N_active*D (inference).

Usage: PYTHONPATH=src python -m benchmarks.roofline [--mesh 16x16]
"""

from __future__ import annotations

import argparse
import glob
import json
import math
import os

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
LINK_BW = 50e9             # bytes/s / link

RESULTS = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                       "results")


def load(mesh: str = "16x16"):
    rows = []
    for path in sorted(glob.glob(os.path.join(RESULTS, "dryrun_*.json"))):
        r = json.load(open(path))
        if r["mesh"] != mesh:
            continue
        rows.append(derive(r))
    return rows


def derive(r: dict) -> dict:
    chips = r["chips"]
    sc = r.get("scaled", {})
    dot_flops = sc.get("dot_flops", 0.0)               # per device
    coll_bytes = sc.get("collective_bytes", 0.0)       # per device
    hbm_hi = sc.get("hbm_bytes_proxy", 0.0)
    hbm_lo = r.get("cost_analysis", {}).get("bytes accessed", 0.0)
    hbm_mid = math.sqrt(max(hbm_hi, 1.0) * max(hbm_lo, 1.0))

    t_compute = dot_flops / PEAK_FLOPS
    t_memory = hbm_mid / HBM_BW
    t_coll = coll_bytes / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)
    step_time = max(terms.values())      # perfect-overlap bound
    model_flops_dev = r["model_flops"] / chips
    useful = model_flops_dev / max(dot_flops, 1.0)
    # roofline fraction: useful-FLOPs MFU implied by the binding term
    mfu_bound = model_flops_dev / PEAK_FLOPS / max(step_time, 1e-12)
    return {
        "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
        "step": r["step"], "chips": chips,
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "step_time_bound_s": step_time,
        "model_flops": r["model_flops"],
        "dot_flops_per_dev": dot_flops,
        "useful_flops_ratio": useful,
        "mfu_bound": mfu_bound,
        "hbm_lo": hbm_lo, "hbm_hi": hbm_hi,
        "coll_bytes_per_dev": coll_bytes,
        "optimizer": r.get("optimizer", "-"),
        "memory_analysis": r.get("memory_analysis", {}),
    }


def fmt_table(rows) -> str:
    hdr = (f"{'arch':<16} {'shape':<12} {'cmp(s)':>9} {'mem(s)':>9} "
           f"{'coll(s)':>9} {'bound(s)':>9} {'dom':<11} {'useful':>7} "
           f"{'MFU≤':>6}")
    out = [hdr, "-" * len(hdr)]
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"])):
        out.append(
            f"{r['arch']:<16} {r['shape']:<12} "
            f"{r['t_compute_s']:>9.4f} {r['t_memory_s']:>9.4f} "
            f"{r['t_collective_s']:>9.4f} {r['step_time_bound_s']:>9.4f} "
            f"{r['dominant']:<11} {r['useful_flops_ratio']:>7.2f} "
            f"{r['mfu_bound']*100:>5.1f}%")
    return "\n".join(out)


def pick_hillclimb(rows):
    """The three most interesting cells: worst roofline fraction, most
    collective-bound, most representative of the technique (MoE train —
    the skew-dispatch arch)."""
    train = [r for r in rows if r["step"] == "train"]
    worst = min(train, key=lambda r: r["mfu_bound"])
    coll = max(rows, key=lambda r: (r["t_collective_s"]
                                    / max(r["step_time_bound_s"], 1e-12)))
    rep = next(r for r in rows
               if r["arch"] == "arctic_480b" and r["shape"] == "train_4k")
    return {"worst_mfu": worst, "most_collective": coll,
            "paper_representative": rep}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()
    rows = load(args.mesh)
    if args.json:
        print(json.dumps(rows, indent=1))
        return
    print(f"Roofline table — mesh {args.mesh} "
          f"(v5e: 197 TF/s, 819 GB/s HBM, 50 GB/s link)\n")
    print(fmt_table(rows))
    print()
    hc = pick_hillclimb(rows)
    print("hillclimb picks:")
    for k, r in hc.items():
        print(f"  {k}: {r['arch']} x {r['shape']} "
              f"(dom={r['dominant']}, MFU-bound {r['mfu_bound']*100:.1f}%)")


if __name__ == "__main__":
    main()
