import os
import sys

# tests see the default single CPU device; multi-device tests spawn
# subprocesses with their own XLA_FLAGS (per the dry-run isolation rule)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
