import os
import sys

# tests see the default single CPU device; multi-device tests spawn
# subprocesses with their own XLA_FLAGS (per the dry-run isolation rule)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# hypothesis is not baked into the TPU container image; fall back to the
# deterministic shim so the property tests still run (real lib wins when
# installed)
try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_shim

    sys.modules["hypothesis"] = _hypothesis_shim
    sys.modules["hypothesis.strategies"] = _hypothesis_shim.strategies

import pytest  # noqa: E402


@pytest.fixture(autouse=True)
def _reset_telemetry():
    """Every test starts from clean metrics + an empty trace buffer —
    counters no longer leak across tests (the historical per-site
    SHUFFLE_STATS key leakage), and no test needs a leading
    ``reset_*_stats()`` call (mid-test re-baselines still do)."""
    from repro.obs import reset_telemetry

    reset_telemetry()
    yield
