"""Codec round trips (encode -> blob -> decode, bit for bit) over
adversarial inputs — empty chunks, single runs, all-distinct data,
int64 extremes, negative deltas, -0.0/NaN float payloads — plus the
append-time ``choose_encoding`` heuristic and blob/member packing
invariants."""

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.storage.encodings import (MIN_WIN, choose_encoding,
                                     decode_chunk, encode_chunk,
                                     payload_rows, run_count,
                                     unpack_members)
from repro.storage.format import zone_stats

I64_MIN = np.iinfo(np.int64).min
I64_MAX = np.iinfo(np.int64).max


def roundtrip(a: np.ndarray, codec: str) -> np.ndarray:
    enc, blob = encode_chunk(a, codec)
    # the blob must survive an npy save cycle byte-identically; a plain
    # copy models that
    got = decode_chunk(enc, np.array(blob))
    assert got.dtype == a.dtype, (codec, got.dtype, a.dtype)
    assert payload_rows(enc, unpack_members(enc, blob)) == a.size
    return got


def assert_bitwise(a: np.ndarray, b: np.ndarray):
    assert a.shape == b.shape
    assert np.array_equal(a.view(np.uint8), b.view(np.uint8))


# ---------------------------------------------------------------------------
# rle
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.integers(0, 200), st.integers(1, 8), st.integers(0, 5))
def test_rle_roundtrip_hypothesis(n, max_run, seed):
    rng = np.random.RandomState(seed)
    vals = []
    while sum(len(v) for v in vals) < n:
        vals.append([rng.randint(-5, 5)] * rng.randint(1, max_run + 1))
    a = np.array([x for v in vals for x in v][:n], np.int64)
    assert_bitwise(a, roundtrip(a, "rle"))


def test_rle_edge_cases():
    for a in (np.zeros(0, np.int64),                    # empty chunk
              np.full(100, 7, np.int64),                # single run
              np.arange(50, dtype=np.int64),            # all distinct
              np.array([I64_MIN, I64_MIN, I64_MAX], np.int64)):
        assert_bitwise(a, roundtrip(a, "rle"))


def test_rle_float_bit_patterns():
    """-0.0 vs 0.0 and NaN payloads are distinct runs and survive the
    round trip bit for bit (value-equality RLE would merge/corrupt
    them)."""
    a = np.array([0.0, -0.0, -0.0, np.nan, np.nan, 1.5], np.float64)
    got = roundtrip(a, "rle")
    assert_bitwise(a, got)
    assert run_count(a) == 4


# ---------------------------------------------------------------------------
# delta
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.integers(0, 300), st.integers(0, 5), st.booleans())
def test_delta_roundtrip_hypothesis(n, seed, negative):
    rng = np.random.RandomState(seed)
    steps = rng.randint(-50 if negative else 0, 51, n)
    a = (np.int64(1) << 40) + np.cumsum(steps).astype(np.int64)
    assert_bitwise(a, roundtrip(a, "delta"))


def test_delta_int64_extremes():
    """Modular uint64 arithmetic keeps the round trip exact across the
    full int64 range (the naive int64 subtraction overflows here)."""
    a = np.array([I64_MIN, I64_MAX, 0, -1, 1, I64_MAX, I64_MIN],
                 np.int64)
    assert_bitwise(a, roundtrip(a, "delta"))


def test_delta_edges():
    for a in (np.zeros(0, np.int64), np.array([42], np.int64),
              np.arange(100, 0, -1, dtype=np.int64),     # negative deltas
              np.full(64, -3, np.int64)):
        assert_bitwise(a, roundtrip(a, "delta"))


# ---------------------------------------------------------------------------
# bitpack
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.integers(0, 200), st.integers(0, 16), st.integers(0, 5),
       st.integers(-1000, 1000))
def test_bitpack_roundtrip_hypothesis(n, span_bits, seed, base):
    rng = np.random.RandomState(seed)
    span = (1 << span_bits) - 1
    a = (base + rng.randint(0, span + 1, n)).astype(np.int64)
    assert_bitwise(a, roundtrip(a, "bitpack"))


def test_bitpack_edges():
    for a in (np.zeros(0, np.int64),
              np.full(33, -9, np.int64),                 # k = 1 floor
              np.arange(-7, 26, dtype=np.int64)):        # ragged words
        assert_bitwise(a, roundtrip(a, "bitpack"))


# ---------------------------------------------------------------------------
# dict
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.integers(0, 200), st.integers(1, 40), st.integers(0, 5),
       st.booleans())
def test_dict_roundtrip_hypothesis(n, card, seed, as_float):
    rng = np.random.RandomState(seed)
    pool = rng.randint(-(10 ** 9), 10 ** 9, card)
    a = pool[rng.randint(0, card, n)].astype(np.int64)
    if as_float:
        a = a.astype(np.float64) / 8.0
    assert_bitwise(a, roundtrip(a, "dict"))


def test_dict_float_specials():
    a = np.array([0.0, -0.0, np.nan, np.nan, 0.07, 0.07], np.float64)
    assert_bitwise(a, roundtrip(a, "dict"))


# ---------------------------------------------------------------------------
# choose_encoding heuristic
# ---------------------------------------------------------------------------

def _z(a):
    return zone_stats(a)


def test_choose_encoding_shapes():
    # sorted label-like runs -> rle
    labels = np.repeat(np.arange(64, dtype=np.int64), 16)
    assert choose_encoding(labels, _z(labels)) == "rle"
    # sorted distinct ints -> delta (1-byte deltas vs 8-byte raw)
    sorted_ids = np.arange(10 ** 6, 10 ** 6 + 512, dtype=np.int64)
    assert choose_encoding(sorted_ids, _z(sorted_ids)) == "delta"
    # random small-range int64 fks: zigzag deltas fit uint16, so delta
    # already clears the 2x bar and wins by codec order
    rng = np.random.RandomState(0)
    fks = rng.randint(0, 512, 1024).astype(np.int64)
    assert choose_encoding(fks, _z(fks)) == "delta"
    # int32 with a ~16-bit span: deltas need uint32 (no win over 4-byte
    # raw) but frame-of-reference bit-packing halves it
    fks32 = rng.randint(0, 60000, 1024).astype(np.int32)
    assert choose_encoding(fks32, _z(fks32)) == "bitpack"
    # low-cardinality floats -> dict (delta/bitpack are int-only)
    prices = np.array([1.25, 2.5, 9.75], np.float64)[
        rng.randint(0, 3, 256)]
    assert choose_encoding(prices, _z(prices)) == "dict"
    # high-entropy floats -> raw
    noise = rng.randn(256)
    assert choose_encoding(noise, _z(noise)) is None
    # tiny chunks never encode
    assert choose_encoding(labels[:7], _z(labels[:7])) is None


@settings(max_examples=20, deadline=None)
@given(st.integers(8, 400), st.integers(0, 5),
       st.sampled_from(["runs", "sorted", "fk", "noise"]))
def test_chosen_codec_always_roundtrips(n, seed, shape):
    """Whatever the heuristic picks must round-trip bit for bit and
    actually win the byte budget it promised."""
    rng = np.random.RandomState(seed)
    if shape == "runs":
        a = np.repeat(rng.randint(0, 5, n), rng.randint(1, 9))[:n] \
            .astype(np.int64)
    elif shape == "sorted":
        a = np.cumsum(rng.randint(0, 3, n)).astype(np.int64)
    elif shape == "fk":
        a = rng.randint(0, 100, n).astype(np.int64)
    else:
        a = rng.randn(n)
    codec = choose_encoding(a, _z(a))
    if codec is None:
        return
    enc, blob = encode_chunk(a, codec)
    assert_bitwise(a, decode_chunk(enc, np.array(blob)))
    assert blob.nbytes * MIN_WIN <= a.nbytes + 64, (
        f"{codec} blob {blob.nbytes}B vs raw {a.nbytes}B — the "
        f"heuristic promised a >= {MIN_WIN}x win")


def test_blob_members_aligned():
    a = np.repeat(np.arange(10, dtype=np.int64), 3)
    enc, blob = encode_chunk(a, "rle")
    for name, dts, count, off in enc["members"]:
        assert off % 8 == 0, (name, off)
    m = unpack_members(enc, blob)
    assert m["values"].size == 10 and m["lengths"].size == 10
