"""Shared fixtures: the running-example query + data generators."""

from __future__ import annotations

import numpy as np

from repro.core import nrc as N

PART_T = N.bag(N.tuple_t(pid=N.INT, pname=N.INT, price=N.REAL))
COP_T = N.bag(N.tuple_t(
    cname=N.INT,
    corders=N.bag(N.tuple_t(
        odate=N.INT,
        oparts=N.bag(N.tuple_t(pid=N.INT, qty=N.REAL))))))

INPUT_TYPES = {"COP": COP_T, "Part": PART_T}


def running_example_query():
    """The paper's Example 1 query (nested-to-nested with sumBy)."""
    COP = N.Var("COP", COP_T)
    Part = N.Var("Part", PART_T)

    def oparts_q(co):
        inner = N.for_in("op", co.oparts, lambda op:
            N.for_in("p", Part, lambda p:
                N.IfThen(op.pid.eq(p.pid),
                         N.Singleton(N.record(pname=p.pname,
                                              total=op.qty * p.price)))))
        return N.SumBy(inner, keys=("pname",), values=("total",))

    return N.for_in("cop", COP, lambda cop: N.Singleton(N.record(
        cname=cop.cname,
        corders=N.for_in("co", cop.corders, lambda co: N.Singleton(N.record(
            odate=co.odate,
            oparts=oparts_q(co)))))))


def gen_parts(n=20, seed=0):
    rng = np.random.RandomState(seed)
    return [{"pid": i, "pname": 100 + i, "price": float(rng.randint(1, 20))}
            for i in range(1, n + 1)]


def gen_cop(n_cust=10, max_orders=4, max_items=8, n_parts=20, seed=1,
            zipf=0.0):
    rng = np.random.RandomState(seed)
    out = []
    for c in range(n_cust):
        orders = []
        for o in range(rng.randint(0, max_orders + 1)):
            items = []
            for _ in range(rng.randint(0, max_items + 1)):
                if zipf > 0 and rng.rand() < zipf:
                    pid = 7
                else:
                    pid = int(rng.randint(1, n_parts + 1))
                items.append({"pid": pid, "qty": float(rng.randint(1, 5))})
            orders.append({"odate": 20200000 + o, "oparts": items})
        out.append({"cname": 1000 + c, "corders": orders})
    return out
