"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracle,
swept over shapes/dtypes (hypothesis + parametrized grids)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.kernels import ref as R
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.rwkv6_scan import rwkv6_pallas
from repro.kernels.segment_reduce import segment_reduce_pallas


# -- segment_reduce -----------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.integers(1, 200), st.integers(1, 5), st.integers(1, 50),
       st.integers(0, 3))
def test_segment_reduce_hypothesis(n, d, num_segments, seed):
    rng = np.random.RandomState(seed)
    seg = np.sort(rng.randint(0, num_segments, n)).astype(np.int32)
    vals = rng.randn(n, d).astype(np.float32)
    got = segment_reduce_pallas(jnp.asarray(vals), jnp.asarray(seg),
                                num_segments, block_rows=32, block_segs=16)
    want = R.segment_reduce_ref(jnp.asarray(vals), jnp.asarray(seg),
                                num_segments)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


def test_segment_reduce_out_of_range_dropped():
    seg = jnp.asarray([-1, 0, 0, 1, 5], jnp.int32)
    vals = jnp.ones((5, 1), jnp.float32)
    got = segment_reduce_pallas(vals, seg, 2, block_rows=5, block_segs=2)
    np.testing.assert_allclose(np.asarray(got[:, 0]), [2.0, 1.0])


@pytest.mark.parametrize("n,num_segments", [
    (33, 7),    # n % block_rows != 0, segments % block_segs != 0
    (32, 7),    # rows aligned, segments ragged
    (33, 8),    # rows ragged, segments aligned
    (5, 50),    # more segments than rows (mostly empty)
])
def test_segment_reduce_padding_edges(n, num_segments):
    rng = np.random.RandomState(7)
    seg = np.sort(rng.randint(0, num_segments, n)).astype(np.int32)
    vals = rng.randint(0, 50, size=(n, 3)).astype(np.float32)
    got = segment_reduce_pallas(jnp.asarray(vals), jnp.asarray(seg),
                                num_segments, block_rows=16, block_segs=8)
    want = R.segment_reduce_ref(jnp.asarray(vals), jnp.asarray(seg),
                                num_segments)
    # integer-valued floats: block accumulation is exact -> bitwise
    assert (np.asarray(got) == np.asarray(want)).all()


def test_segment_reduce_all_invalid():
    n, num_segments = 19, 6
    seg = jnp.full((n,), -1, jnp.int32)   # the invalid-row sentinel
    vals = jnp.ones((n, 2), jnp.float32)
    got = segment_reduce_pallas(vals, seg, num_segments, block_rows=8,
                                block_segs=4)
    assert (np.asarray(got) == 0).all()


# -- fused segment-sum + first-row gather -------------------------------------

from repro.kernels.segment_fused import segment_sum_first_pallas  # noqa: E402
from repro.kernels.gather_join import (  # noqa: E402
    gather_rows_pallas, merge_positions_pallas)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 80), st.integers(1, 3), st.integers(1, 40),
       st.integers(1, 3), st.integers(0, 3))
def test_segment_sum_first_hypothesis(n, d, num_segments, k, seed):
    rng = np.random.RandomState(seed)
    seg = np.sort(rng.randint(0, num_segments, n)).astype(np.int32)
    vals = rng.randint(0, 100, size=(n, d)).astype(np.float32)
    keys = rng.randint(-2 ** 62, 2 ** 62, size=(n, k)).astype(np.int64)
    got = segment_sum_first_pallas(jnp.asarray(vals), jnp.asarray(keys),
                                   jnp.asarray(seg), num_segments,
                                   block_rows=16, block_segs=8)
    want = R.segment_sum_first_ref(jnp.asarray(vals), jnp.asarray(keys),
                                   jnp.asarray(seg), num_segments)
    for g, w in zip(got, want):
        assert (np.asarray(g) == np.asarray(w)).all()


def test_segment_sum_first_all_invalid():
    n, S = 13, 5
    seg = jnp.full((n,), -1, jnp.int32)
    vals = jnp.ones((n, 2), jnp.float32)
    keys = jnp.ones((n, 1), jnp.int64)
    sums, fidx, fvals = segment_sum_first_pallas(vals, keys, seg, S,
                                                 block_rows=4, block_segs=4)
    assert (np.asarray(sums) == 0).all()
    assert (np.asarray(fidx) == np.iinfo(np.int32).max).all()
    assert (np.asarray(fvals) == 0).all()


# -- blocked merge-join positions + one-hot gather ----------------------------

@settings(max_examples=15, deadline=None)
@given(st.integers(1, 100), st.integers(1, 80), st.integers(0, 3))
def test_merge_positions_hypothesis(r, n, seed):
    rng = np.random.RandomState(seed)
    srk = np.sort(rng.randint(-20, 20, r)).astype(np.int64)
    q = rng.randint(-25, 25, n).astype(np.int64)
    lo, hi = merge_positions_pallas(jnp.asarray(srk), jnp.asarray(q),
                                    block_q=16, block_r=16)
    rlo, rhi = R.merge_positions_ref(jnp.asarray(srk), jnp.asarray(q))
    assert (np.asarray(lo) == np.asarray(rlo)).all()
    assert (np.asarray(hi) == np.asarray(rhi)).all()


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 60), st.integers(1, 60), st.integers(1, 4),
       st.integers(0, 3))
def test_gather_rows_hypothesis(r, n, d, seed):
    rng = np.random.RandomState(seed)
    vals = rng.randint(-2 ** 62, 2 ** 62, size=(r, d)).astype(np.int64)
    idx = rng.randint(-3, r + 3, n).astype(np.int32)   # includes oob
    got = gather_rows_pallas(jnp.asarray(vals), jnp.asarray(idx),
                             block_n=16, block_src=16)
    want = R.gather_rows_ref(jnp.asarray(vals), jnp.asarray(idx))
    assert (np.asarray(got) == np.asarray(want)).all()


# -- packed-shuffle dest-scatter + column unpack ------------------------------

from repro.kernels.shuffle_pack import (  # noqa: E402
    member_mask_pallas, pack_rows_pallas, unpack_cols_pallas)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 60), st.integers(1, 60), st.integers(1, 4),
       st.integers(0, 3))
def test_pack_rows_hypothesis(r, m, d, seed):
    rng = np.random.RandomState(seed)
    vals = rng.randint(-2 ** 62, 2 ** 62, size=(r, d)).astype(np.int64)
    idx = rng.randint(-3, r + 3, m).astype(np.int32)   # includes oob
    ok = rng.randint(0, 2, m).astype(bool)
    got = pack_rows_pallas(jnp.asarray(vals), jnp.asarray(idx),
                           jnp.asarray(ok), block_m=16, block_src=16)
    want = R.pack_rows_ref(jnp.asarray(vals), jnp.asarray(idx),
                           jnp.asarray(ok))
    assert (np.asarray(got) == np.asarray(want)).all()


def test_pack_rows_all_masked():
    vals = jnp.ones((9, 2), jnp.int64)
    idx = jnp.arange(9, dtype=jnp.int32)
    ok = jnp.zeros((9,), bool)
    got = pack_rows_pallas(vals, idx, ok, block_m=4, block_src=4)
    assert (np.asarray(got) == 0).all()


from repro.kernels.shuffle_pack import replicate_scatter_pallas  # noqa: E402


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 60), st.integers(1, 80), st.integers(1, 4),
       st.integers(1, 6), st.integers(0, 3))
def test_replicate_scatter_hypothesis(r, m, d, repl, seed):
    """Hypercube replicating dest-scatter == oracle, bit for bit:
    virtual ids cover every replica of every source row plus
    out-of-range on both ends (the -1 pad sentinel included)."""
    rng = np.random.RandomState(seed)
    vals = rng.randint(-2 ** 62, 2 ** 62, size=(r, d)).astype(np.int64)
    vidx = rng.randint(-3, r * repl + 5, m).astype(np.int32)
    ok = rng.randint(0, 2, m).astype(bool)
    got = replicate_scatter_pallas(jnp.asarray(vals), jnp.asarray(vidx),
                                   jnp.asarray(ok), repl,
                                   block_m=16, block_src=16)
    want = R.replicate_scatter_ref(jnp.asarray(vals), jnp.asarray(vidx),
                                   jnp.asarray(ok), repl)
    assert (np.asarray(got) == np.asarray(want)).all()


def test_replicate_scatter_repl_one_matches_pack_rows():
    """repl=1 degenerates to pack_rows exactly (same routing
    contract), so the hypercube exchange with no replicated dims costs
    what the binary exchange costs."""
    rng = np.random.RandomState(0)
    vals = rng.randint(-2 ** 62, 2 ** 62, size=(20, 3)).astype(np.int64)
    idx = rng.randint(-2, 22, 33).astype(np.int32)
    ok = rng.randint(0, 2, 33).astype(bool)
    a = replicate_scatter_pallas(jnp.asarray(vals), jnp.asarray(idx),
                                 jnp.asarray(ok), 1, block_m=8,
                                 block_src=8)
    b = pack_rows_pallas(jnp.asarray(vals), jnp.asarray(idx),
                         jnp.asarray(ok), block_m=8, block_src=8)
    assert (np.asarray(a) == np.asarray(b)).all()


def test_replicate_scatter_each_replica_lands():
    """Every replica q of source row i is addressable: vidx = i*repl+q
    gathers row i for all q."""
    repl, r = 3, 5
    vals = (jnp.arange(r, dtype=jnp.int64) * 10)[:, None]
    vidx = jnp.arange(r * repl, dtype=jnp.int32)
    ok = jnp.ones((r * repl,), bool)
    got = replicate_scatter_pallas(vals, vidx, ok, repl, block_m=4,
                                   block_src=4)
    want = np.repeat(np.arange(r) * 10, repl)[:, None]
    assert (np.asarray(got) == want).all()


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 70), st.integers(1, 5), st.integers(0, 3))
def test_unpack_cols_hypothesis(m, d, seed):
    rng = np.random.RandomState(seed)
    buf = rng.randint(-2 ** 62, 2 ** 62, size=(m, d)).astype(np.int64)
    got = unpack_cols_pallas(jnp.asarray(buf), block_t=16)
    want = R.unpack_cols_ref(jnp.asarray(buf))
    assert (np.asarray(got) == np.asarray(want)).all()


@settings(max_examples=12, deadline=None)
@given(st.integers(1, 70), st.integers(0, 12), st.integers(0, 3))
def test_member_mask_hypothesis(n, n_heavy, seed):
    """Heavy-key membership kernel == ref == searchsorted semantics,
    I64_MAX padding inert on both sides."""
    I64 = np.iinfo(np.int64).max
    rng = np.random.RandomState(seed)
    keys = rng.randint(-40, 40, n).astype(np.int64)
    if n > 2:
        keys[rng.randint(0, n, max(n // 4, 1))] = I64   # padded keys
    heavy = np.full(40, I64, np.int64)
    heavy[:n_heavy] = np.sort(rng.choice(
        np.arange(-40, 40), size=n_heavy, replace=False)).astype(np.int64)
    got = member_mask_pallas(jnp.asarray(keys), jnp.asarray(heavy),
                             block_n=16)
    want = R.member_mask_ref(jnp.asarray(keys), jnp.asarray(heavy))
    assert (np.asarray(got) == np.asarray(want)).all()
    from repro.core.skew import is_member
    srch = is_member(jnp.asarray(keys), jnp.asarray(heavy))
    assert (np.asarray(want) == np.asarray(srch)).all()


# -- flash attention -----------------------------------------------------------

ATTN_VARIANTS = [
    dict(causal=True),
    dict(causal=False),
    dict(causal=True, window=5),
    dict(causal=True, softcap=20.0),
    dict(causal=True, window=9, softcap=30.0),
]


@pytest.mark.parametrize("kwargs", ATTN_VARIANTS)
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(1, 2, 2, 24, 16), (2, 4, 2, 33, 8)])
def test_flash_attention_variants(kwargs, dtype, shape):
    B, H, Hkv, S, D = shape
    rng = np.random.RandomState(0)
    q = jnp.asarray(rng.randn(B, H, S, D), dtype)
    k = jnp.asarray(rng.randn(B, Hkv, S, D), dtype)
    v = jnp.asarray(rng.randn(B, Hkv, S, D), dtype)
    got = flash_attention_pallas(q, k, v, block_q=16, block_k=16, **kwargs)
    want = R.attention_ref(q, k, v, **kwargs)
    atol = 2e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=atol)


@settings(max_examples=8, deadline=None)
@given(st.integers(8, 64), st.integers(1, 2), st.integers(0, 3))
def test_flash_attention_hypothesis(S, B, seed):
    rng = np.random.RandomState(seed)
    H, Hkv, D = 2, 1, 8
    q = jnp.asarray(rng.randn(B, H, S, D), jnp.float32)
    k = jnp.asarray(rng.randn(B, Hkv, S, D), jnp.float32)
    v = jnp.asarray(rng.randn(B, Hkv, S, D), jnp.float32)
    got = flash_attention_pallas(q, k, v, causal=True, block_q=16,
                                 block_k=16)
    want = R.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-3)


# -- rwkv6 ---------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(st.integers(4, 40), st.integers(1, 2), st.integers(0, 2),
       st.sampled_from([4, 16]))
def test_rwkv6_hypothesis(T, B, seed, chunk):
    rng = np.random.RandomState(seed)
    H, K, V = 2, 8, 8
    r = jnp.asarray(rng.randn(B, H, T, K) * 0.5, jnp.float32)
    k = jnp.asarray(rng.randn(B, H, T, K) * 0.5, jnp.float32)
    v = jnp.asarray(rng.randn(B, H, T, V), jnp.float32)
    w = jnp.asarray(0.2 + 0.79 * rng.rand(B, H, T, K), jnp.float32)
    u = jnp.asarray(rng.randn(H, K) * 0.3, jnp.float32)
    got = rwkv6_pallas(r, k, v, w, u, chunk=chunk)
    want = R.rwkv6_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-3, rtol=1e-3)


def test_rwkv6_chunk_invariance():
    """Chunk size must not change the result (state hand-off exactness)."""
    rng = np.random.RandomState(1)
    B, H, T, K = 1, 1, 37, 4
    r = jnp.asarray(rng.randn(B, H, T, K), jnp.float32)
    k = jnp.asarray(rng.randn(B, H, T, K), jnp.float32)
    v = jnp.asarray(rng.randn(B, H, T, K), jnp.float32)
    w = jnp.asarray(0.5 + 0.49 * rng.rand(B, H, T, K), jnp.float32)
    u = jnp.asarray(rng.randn(H, K), jnp.float32)
    o1 = rwkv6_pallas(r, k, v, w, u, chunk=8)
    o2 = rwkv6_pallas(r, k, v, w, u, chunk=16)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-4)


def test_jnp_chunked_matches_pallas():
    """The XLA-native model path (ssm.rwkv6_chunked) and the Pallas
    kernel implement the same math."""
    from repro.models.ssm import rwkv6_chunked
    rng = np.random.RandomState(2)
    B, H, T, K = 1, 2, 20, 4
    r = jnp.asarray(rng.randn(B, H, T, K), jnp.float32)
    k = jnp.asarray(rng.randn(B, H, T, K), jnp.float32)
    v = jnp.asarray(rng.randn(B, H, T, K), jnp.float32)
    w = jnp.asarray(0.5 + 0.49 * rng.rand(B, H, T, K), jnp.float32)
    u = jnp.asarray(rng.randn(H, K), jnp.float32)
    o1 = rwkv6_pallas(r, k, v, w, u, chunk=8)
    o2 = rwkv6_chunked(r, k, v, w, u, chunk=8)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-4)


# -- compressed-chunk decode kernels ------------------------------------------

from repro.kernels.decode import (  # noqa: E402
    bitunpack_pallas, delta_unpack_pallas, dict_gather_pallas,
    rle_expand_pallas)
from repro.storage import encodings as E  # noqa: E402

I64_MIN = np.iinfo(np.int64).min
I64_MAX = np.iinfo(np.int64).max


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 300), st.integers(1, 9), st.integers(0, 4))
def test_rle_expand_hypothesis(n, max_run, seed):
    rng = np.random.RandomState(seed)
    lengths = []
    while sum(lengths) < n:
        lengths.append(rng.randint(1, max_run + 1))
    lengths[-1] -= sum(lengths) - n
    lengths = np.array([l for l in lengths if l], np.int64)
    values = rng.randint(I64_MIN, I64_MAX, lengths.size,
                         dtype=np.int64)
    ends = np.cumsum(lengths)
    starts = ends - lengths
    got = rle_expand_pallas(jnp.asarray(values), jnp.asarray(starts),
                            jnp.asarray(ends), n, block_n=64,
                            block_r=32)
    want = R.rle_expand_ref(jnp.asarray(values), jnp.asarray(starts),
                            jnp.asarray(ends), n)
    assert (np.asarray(got) == np.asarray(want)).all()
    assert (np.asarray(got) == np.repeat(values, lengths)).all()


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 300), st.integers(0, 4), st.booleans())
def test_delta_unpack_hypothesis(n, seed, extreme):
    rng = np.random.RandomState(seed)
    if extreme:
        a = rng.randint(I64_MIN, I64_MAX, n, dtype=np.int64)
    else:
        a = np.cumsum(rng.randint(-100, 100, n)).astype(np.int64)
    enc, blob = E.encode_chunk(a, "delta")
    z = E.unpack_members(enc, blob)["deltas"].astype(np.uint64)
    first = np.array([enc["first"]], np.uint64)
    got = delta_unpack_pallas(jnp.asarray(z), jnp.asarray(first),
                              block_n=64)
    want = R.delta_unpack_ref(jnp.asarray(z), jnp.asarray(first))
    assert (np.asarray(got) == np.asarray(want)).all()
    assert (np.asarray(got) == a).all()


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 300), st.integers(0, 16), st.integers(0, 4))
def test_bitunpack_hypothesis(n, span_bits, seed):
    rng = np.random.RandomState(seed)
    a = (-37 + rng.randint(0, (1 << span_bits), n)).astype(np.int64)
    enc, blob = E.encode_chunk(a, "bitpack")
    words = E.unpack_members(enc, blob)["words"].astype(np.uint32)
    got = bitunpack_pallas(jnp.asarray(words), enc["k"], enc["vpw"],
                           enc["n"], enc["lo"], block_w=32)
    want = R.bitunpack_ref(jnp.asarray(words), enc["k"], enc["vpw"],
                           enc["n"], enc["lo"])
    assert (np.asarray(got) == np.asarray(want)).all()
    assert (np.asarray(got) == a).all()


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 300), st.integers(1, 40), st.integers(0, 4))
def test_dict_gather_hypothesis(n, card, seed):
    rng = np.random.RandomState(seed)
    values = np.unique(rng.randint(I64_MIN, I64_MAX, card,
                                   dtype=np.int64))
    codes = rng.randint(0, values.size, n).astype(np.int32)
    got = dict_gather_pallas(jnp.asarray(values), jnp.asarray(codes),
                             block_n=64, block_v=16)
    want = R.dict_gather_ref(jnp.asarray(values), jnp.asarray(codes))
    assert (np.asarray(got) == np.asarray(want)).all()
    assert (np.asarray(got) == values[codes]).all()


def test_decode_kernels_match_numpy_codecs():
    """kernels.ops wrappers (kernel dispatch layer) == the NumPy codec
    decode, over every codec on one adversarial array each."""
    from repro.kernels import ops as K
    rng = np.random.RandomState(3)
    rle_a = np.repeat(
        np.array([I64_MIN, -1, 0, I64_MAX, 7], np.int64), [3, 1, 4, 2, 5])
    enc, blob = E.encode_chunk(rle_a, "rle")
    m = E.unpack_members(enc, blob)
    lengths = m["lengths"].astype(np.int64)
    ends = np.cumsum(lengths)
    got = K.rle_expand(jnp.asarray(m["values"]),
                       jnp.asarray(ends - lengths), jnp.asarray(ends),
                       int(ends[-1]))
    assert (np.asarray(got) == rle_a).all()

    da = np.cumsum(rng.randint(-9, 9, 100)).astype(np.int64)
    enc, blob = E.encode_chunk(da, "delta")
    got = K.delta_unpack(
        jnp.asarray(E.unpack_members(enc, blob)["deltas"]
                    .astype(np.uint64)),
        jnp.asarray(np.array([enc["first"]], np.uint64)))
    assert (np.asarray(got) == da).all()

    ba = rng.randint(0, 1000, 77).astype(np.int64)
    enc, blob = E.encode_chunk(ba, "bitpack")
    got = K.bitunpack(
        jnp.asarray(E.unpack_members(enc, blob)["words"]
                    .astype(np.uint32)),
        enc["k"], enc["vpw"], enc["n"], enc["lo"])
    assert (np.asarray(got) == ba).all()

    fa = np.array([0.0, -0.0, np.nan, 2.5], np.float64)[
        rng.randint(0, 4, 50)]
    enc, blob = E.encode_chunk(fa, "dict")
    m = E.unpack_members(enc, blob)
    got = K.dict_gather(jnp.asarray(m["values"].view(np.int64)),
                        jnp.asarray(m["codes"].astype(np.int32)))
    assert (np.asarray(got).view(np.float64).view(np.uint8)
            == fa.view(np.uint8)).all()
