"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracle,
swept over shapes/dtypes (hypothesis + parametrized grids)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.kernels import ref as R
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.rwkv6_scan import rwkv6_pallas
from repro.kernels.segment_reduce import segment_reduce_pallas


# -- segment_reduce -----------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.integers(1, 200), st.integers(1, 5), st.integers(1, 50),
       st.integers(0, 3))
def test_segment_reduce_hypothesis(n, d, num_segments, seed):
    rng = np.random.RandomState(seed)
    seg = np.sort(rng.randint(0, num_segments, n)).astype(np.int32)
    vals = rng.randn(n, d).astype(np.float32)
    got = segment_reduce_pallas(jnp.asarray(vals), jnp.asarray(seg),
                                num_segments, block_rows=32, block_segs=16)
    want = R.segment_reduce_ref(jnp.asarray(vals), jnp.asarray(seg),
                                num_segments)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


def test_segment_reduce_out_of_range_dropped():
    seg = jnp.asarray([-1, 0, 0, 1, 5], jnp.int32)
    vals = jnp.ones((5, 1), jnp.float32)
    got = segment_reduce_pallas(vals, seg, 2, block_rows=5, block_segs=2)
    np.testing.assert_allclose(np.asarray(got[:, 0]), [2.0, 1.0])


# -- flash attention -----------------------------------------------------------

ATTN_VARIANTS = [
    dict(causal=True),
    dict(causal=False),
    dict(causal=True, window=5),
    dict(causal=True, softcap=20.0),
    dict(causal=True, window=9, softcap=30.0),
]


@pytest.mark.parametrize("kwargs", ATTN_VARIANTS)
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(1, 2, 2, 24, 16), (2, 4, 2, 33, 8)])
def test_flash_attention_variants(kwargs, dtype, shape):
    B, H, Hkv, S, D = shape
    rng = np.random.RandomState(0)
    q = jnp.asarray(rng.randn(B, H, S, D), dtype)
    k = jnp.asarray(rng.randn(B, Hkv, S, D), dtype)
    v = jnp.asarray(rng.randn(B, Hkv, S, D), dtype)
    got = flash_attention_pallas(q, k, v, block_q=16, block_k=16, **kwargs)
    want = R.attention_ref(q, k, v, **kwargs)
    atol = 2e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=atol)


@settings(max_examples=8, deadline=None)
@given(st.integers(8, 64), st.integers(1, 2), st.integers(0, 3))
def test_flash_attention_hypothesis(S, B, seed):
    rng = np.random.RandomState(seed)
    H, Hkv, D = 2, 1, 8
    q = jnp.asarray(rng.randn(B, H, S, D), jnp.float32)
    k = jnp.asarray(rng.randn(B, Hkv, S, D), jnp.float32)
    v = jnp.asarray(rng.randn(B, Hkv, S, D), jnp.float32)
    got = flash_attention_pallas(q, k, v, causal=True, block_q=16,
                                 block_k=16)
    want = R.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-3)


# -- rwkv6 ---------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(st.integers(4, 40), st.integers(1, 2), st.integers(0, 2),
       st.sampled_from([4, 16]))
def test_rwkv6_hypothesis(T, B, seed, chunk):
    rng = np.random.RandomState(seed)
    H, K, V = 2, 8, 8
    r = jnp.asarray(rng.randn(B, H, T, K) * 0.5, jnp.float32)
    k = jnp.asarray(rng.randn(B, H, T, K) * 0.5, jnp.float32)
    v = jnp.asarray(rng.randn(B, H, T, V), jnp.float32)
    w = jnp.asarray(0.2 + 0.79 * rng.rand(B, H, T, K), jnp.float32)
    u = jnp.asarray(rng.randn(H, K) * 0.3, jnp.float32)
    got = rwkv6_pallas(r, k, v, w, u, chunk=chunk)
    want = R.rwkv6_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-3, rtol=1e-3)


def test_rwkv6_chunk_invariance():
    """Chunk size must not change the result (state hand-off exactness)."""
    rng = np.random.RandomState(1)
    B, H, T, K = 1, 1, 37, 4
    r = jnp.asarray(rng.randn(B, H, T, K), jnp.float32)
    k = jnp.asarray(rng.randn(B, H, T, K), jnp.float32)
    v = jnp.asarray(rng.randn(B, H, T, K), jnp.float32)
    w = jnp.asarray(0.5 + 0.49 * rng.rand(B, H, T, K), jnp.float32)
    u = jnp.asarray(rng.randn(H, K), jnp.float32)
    o1 = rwkv6_pallas(r, k, v, w, u, chunk=8)
    o2 = rwkv6_pallas(r, k, v, w, u, chunk=16)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-4)


def test_jnp_chunked_matches_pallas():
    """The XLA-native model path (ssm.rwkv6_chunked) and the Pallas
    kernel implement the same math."""
    from repro.models.ssm import rwkv6_chunked
    rng = np.random.RandomState(2)
    B, H, T, K = 1, 2, 20, 4
    r = jnp.asarray(rng.randn(B, H, T, K), jnp.float32)
    k = jnp.asarray(rng.randn(B, H, T, K), jnp.float32)
    v = jnp.asarray(rng.randn(B, H, T, K), jnp.float32)
    w = jnp.asarray(0.5 + 0.49 * rng.rand(B, H, T, K), jnp.float32)
    u = jnp.asarray(rng.randn(H, K), jnp.float32)
    o1 = rwkv6_pallas(r, k, v, w, u, chunk=8)
    o2 = rwkv6_chunked(r, k, v, w, u, chunk=8)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-4)
