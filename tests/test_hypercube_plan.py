"""HyperCube shuffle planning and the one-round multiway join: the
share-assignment cost model (``skew.plan_hypercube_shares``), the chain
recognizer / rewriter (``plans.apply_hypercube_program``), the
``MultiJoinP`` lowering through ``exec.dist.multi_join``, and the
degenerate cases — P=1 and prime P meshes, a tiny relation (share 1 ==
broadcast), a replication-dominated star the cost gate must refuse,
and heavy-key sets absorbed from the skew pass rebinding with zero
retraces.

Distributed assertions run on a single-device mesh (collective counts
and trace counts are trace-time host counters); the 8-virtual-device
wire behavior is covered by the differential suite's subprocess lane
and ``benchmarks/hypercube.py``."""

import numpy as np
import pytest

from repro.columnar.table import FlatBag
from repro.core import codegen as CG
from repro.core import plans as P
from repro.core import skew as SK
from repro.exec import dist as D
from repro.exec.dist import device_mesh_1d


# ---------------------------------------------------------------------------
# the share planner (cost model)
# ---------------------------------------------------------------------------

def test_shares_respect_budget_and_chain_shape():
    """A 2-dim chain with a dominant spine splits the mesh across both
    dimensions; the product of shares never exceeds P."""
    rel_dims = [(0, 1), (0,), (1,)]       # spine keys both dims
    rows = [10000, 100, 100]
    shares, load = SK.plan_hypercube_shares(rel_dims, rows, 16)
    assert len(shares) == 2
    assert shares[0] * shares[1] <= 16
    assert shares[0] > 1 and shares[1] > 1     # spine splits both ways
    assert load <= rows[0]                     # strictly better than P=1


def test_shares_degenerate_meshes():
    # P=1: all shares are 1, load is the full input
    shares, load = SK.plan_hypercube_shares([(0, 1), (0,), (1,)],
                                            [100, 10, 10], 1)
    assert shares == (1, 1)
    # prime P: the whole mesh lands on one dimension (the heavier one)
    shares, _ = SK.plan_hypercube_shares([(0, 1), (0,), (1,)],
                                         [10000, 500, 10], 7)
    assert sorted(shares) == [1, 7]
    assert shares[0] == 7                  # dim 0 carries the big build
    # tiny relation: its dimension gets share 1 -> it broadcasts
    shares, _ = SK.plan_hypercube_shares([(0, 1), (0,), (1,)],
                                         [10000, 10000, 2], 8)
    assert shares[1] == 1 and shares[0] == 8


def test_send_rows_cost_model():
    rel_dims = [(0, 1), (0,), (1,)]
    rows = [1000, 50, 60]
    hc = SK.hypercube_send_rows(rel_dims, rows, (4, 2))
    # spine ships once, B replicates over dim1 (x2), C over dim0 (x4)
    assert hc == 1000 + 50 * 2 + 60 * 4
    # cascade: all relations once + the spine again per extra join
    assert SK.cascade_send_rows(rows) == 1110 + 1000


# ---------------------------------------------------------------------------
# plan construction helpers
# ---------------------------------------------------------------------------

def chain_plan():
    j1 = P.JoinP(P.ScanP("A", "a"), P.ScanP("B", "b"),
                 ("a.k",), ("b.k",))
    return P.JoinP(j1, P.ScanP("C", "c"), ("a.c",), ("c.c",))


def chain_env(n=64, seed=0, hot=None):
    rng = np.random.RandomState(seed)
    ks = [hot if (hot is not None and rng.rand() < 0.5)
          else int(rng.randint(0, 16)) for _ in range(n)]
    A = FlatBag.from_rows(
        [{"k": k, "v": float(rng.randint(1, 5)), "c": int(rng.randint(0, 8))}
         for k in ks],
        {"k": "int", "v": "real", "c": "int"}, capacity=n)
    B = FlatBag.from_rows(
        [{"k": i, "w": float(10 * i)} for i in range(16)],
        {"k": "int", "w": "real"}, capacity=16)
    C = FlatBag.from_rows(
        [{"c": i, "z": float(100 * i)} for i in range(8)],
        {"c": "int", "z": "real"}, capacity=8)
    return {"A": A, "B": B, "C": C}


def chain_stats(n=64, heavy=()):
    return {"A": SK.TableStats(rows=n, distinct={"k": 16},
                               heavy={"k": [(int(k), n // 2)
                                            for k in heavy]}),
            "B": SK.TableStats(rows=16, distinct={"k": 16}, heavy={}),
            "C": SK.TableStats(rows=8, distinct={"c": 8}, heavy={})}


def rows_of(bag, cols):
    out = []
    host = {c: np.asarray(bag.col(c)) for c in cols}
    for i, ok in enumerate(np.asarray(bag.valid)):
        if ok:
            out.append(tuple(host[c][i] for c in cols))
    return sorted(out)


def multi_nodes(plan):
    return [s for s in P._walk_plan(plan) if isinstance(s, P.MultiJoinP)]


# ---------------------------------------------------------------------------
# recognition / rewrite
# ---------------------------------------------------------------------------

def test_rewrite_chain_to_multijoin():
    g = P.build_program_graph([("Q", chain_plan())], outputs=("Q",))
    n = P.apply_hypercube_program(g, chain_stats(), n_partitions=8)
    (nd,) = g.nodes
    (mj,) = multi_nodes(nd.plan)
    assert n == 1
    assert len(mj.stages) == 2 and len(mj.shares) == 2
    # spine probes both dims; each build relation owns exactly one
    assert [r for _, _, r in mj.rel_routes[0]] == ["probe", "probe"]
    assert [r for _, _, r in mj.rel_routes[1]] == ["build"]
    assert "MultiJoin" in P.plan_pretty(nd.plan)


def test_single_join_not_rewritten():
    j = P.JoinP(P.ScanP("A", "a"), P.ScanP("B", "b"), ("a.k",), ("b.k",))
    g = P.build_program_graph([("Q", j)], outputs=("Q",))
    assert P.apply_hypercube_program(g, chain_stats(), 8) == 0
    assert multi_nodes(g.nodes[0].plan) == []


def test_outer_join_breaks_chain():
    j1 = P.JoinP(P.ScanP("A", "a"), P.ScanP("B", "b"),
                 ("a.k",), ("b.k",), how="left_outer")
    j2 = P.JoinP(j1, P.ScanP("C", "c"), ("a.c",), ("c.c",))
    g = P.build_program_graph([("Q", j2)], outputs=("Q",))
    assert P.apply_hypercube_program(g, chain_stats(), 8) == 0


def test_missing_stats_bail():
    g = P.build_program_graph([("Q", chain_plan())], outputs=("Q",))
    partial = chain_stats()
    del partial["C"]
    assert P.apply_hypercube_program(g, partial, 8) == 0


def test_cost_gate_refuses_replication_dominated_star():
    """Two big build relations on distinct dimensions: any share split
    replicates one of them massively; the cascade ships less, so the
    rewrite must not fire."""
    g = P.build_program_graph([("Q", chain_plan())], outputs=("Q",))
    stats = {"A": SK.TableStats(rows=10, distinct={}, heavy={}),
             "B": SK.TableStats(rows=10000, distinct={}, heavy={}),
             "C": SK.TableStats(rows=10000, distinct={}, heavy={})}
    assert P.apply_hypercube_program(g, stats, 8) == 0


def test_rewrite_idempotent():
    g = P.build_program_graph([("Q", chain_plan())], outputs=("Q",))
    assert P.apply_hypercube_program(g, chain_stats(), 8) == 1
    assert P.apply_hypercube_program(g, chain_stats(), 8) == 0


def test_fused_join_agg_unfuses_to_multijoin():
    agg = P.push_order(P.SumAggP(chain_plan(), keys=("b.w",),
                                 vals=("a.v",)))
    assert isinstance(agg, P.FusedJoinAggP)
    g = P.build_program_graph([("Q", agg)], outputs=("Q",))
    assert P.apply_hypercube_program(g, chain_stats(), 8) == 1
    (nd,) = g.nodes
    assert isinstance(nd.plan, P.SumAggP)
    assert isinstance(nd.plan.child, P.MultiJoinP)


def test_skew_params_absorbed_and_signature_stable():
    """SkewJoinP wrappers inside the chain dissolve into per-dimension
    heavy params under the SAME names, and the plan signature is
    deterministic (CSE-safe)."""
    g = P.build_program_graph([("Q", chain_plan())], outputs=("Q",))
    info = P.apply_skew_program(g, chain_stats(heavy=[7]), n_partitions=8)
    assert list(info) == ["__hk0"]
    assert P.apply_hypercube_program(g, chain_stats(heavy=[7]), 8) == 1
    (mj,) = multi_nodes(g.nodes[0].plan)
    assert "__hk0" in mj.heavy_params
    assert P.collect_plan_params(g)["__hk0"].shape == (SK.MAX_HEAVY,)
    g2 = P.build_program_graph([("Q", chain_plan())], outputs=("Q",))
    P.apply_skew_program(g2, chain_stats(heavy=[7]), n_partitions=8)
    P.apply_hypercube_program(g2, chain_stats(heavy=[7]), 8)
    assert P._plan_sig(g.nodes[0].plan, P._Canon()) \
        == P._plan_sig(g2.nodes[0].plan, P._Canon())


def test_shared_relation_sketched_once():
    """Two joins probing the same (bag, attr): one heavy-key param is
    decided once and shared (the per-compile stats hoist)."""
    j1 = P.JoinP(P.ScanP("A", "a"), P.ScanP("B", "b"),
                 ("a.k",), ("b.k",))
    j2 = P.JoinP(P.ScanP("A", "a2"), P.ScanP("C", "c"),
                 ("a2.k",), ("c.c",))
    g = P.build_program_graph([("Q1", j1), ("Q2", j2)],
                              outputs=("Q1", "Q2"))
    info = P.apply_skew_program(g, chain_stats(heavy=[7]), n_partitions=8)
    assert len(info) == 1          # one param for the shared (A, k)
    sjs = [s for nd in g.nodes for s in P._walk_plan(nd.plan)
           if isinstance(s, P.SkewJoinP)]
    assert len(sjs) == 2
    assert sjs[0].heavy_param == sjs[1].heavy_param


# ---------------------------------------------------------------------------
# evaluation parity (local + single-device dist + degenerate meshes)
# ---------------------------------------------------------------------------

COLS = ("a.k", "a.v", "b.w", "c.z")


def _rewritten(stats, n_partitions=8, skew=False):
    g = P.build_program_graph([("Q", chain_plan())], outputs=("Q",))
    if skew:
        P.apply_skew_program(g, stats, n_partitions=n_partitions)
    P.apply_hypercube_program(g, stats, n_partitions=n_partitions)
    return g


def test_local_eval_parity():
    env = chain_env()
    want = rows_of(P.eval_plan(chain_plan(), dict(env)), COLS)
    g = _rewritten(chain_stats())
    assert multi_nodes(g.nodes[0].plan)
    got = rows_of(P.eval_plan(g.nodes[0].plan, dict(env)), COLS)
    assert got == want


@pytest.mark.parametrize("n_partitions", [1, 3, 8])
def test_dist_eval_parity_share_plans(n_partitions):
    """Share planning at P in {1, prime, 8} all execute correctly on a
    one-device mesh (the wire layout is P-independent)."""
    env = chain_env(seed=3)
    want = rows_of(P.eval_plan(chain_plan(), dict(env)), COLS)
    g = _rewritten(chain_stats(), n_partitions=n_partitions)
    (nd,) = g.nodes

    def fn(env_local, ctx, params_local):
        s = P.ExecSettings(dist=ctx, params=params_local)
        return {"Q": P.eval_plan(nd.plan, dict(env_local), s)}

    runner, out, m = D.compile_distributed(fn, env, device_mesh_1d(1),
                                           cap_factor=16.0, params={})
    assert rows_of(out["Q"], COLS) == want
    assert m["hypercube_exchanges"] == 1
    assert m["shuffle_collectives"] == 1      # ONE round for 3 relations


def test_dist_heavy_rebind_zero_retraces():
    """Heavy sets absorbed into hypercube dimensions rebind on the warm
    runner with zero retraces and unchanged results."""
    env = chain_env(seed=5, hot=7)
    want = rows_of(P.eval_plan(chain_plan(), dict(env)), COLS)
    g = _rewritten(chain_stats(heavy=[7]), skew=True)
    (nd,) = g.nodes
    (mj,) = multi_nodes(nd.plan)
    assert any(h is not None for h in mj.heavy_params)
    defaults = P.collect_plan_params(g)
    (name,) = list(defaults)

    def fn(env_local, ctx, params_local):
        s = P.ExecSettings(dist=ctx, params=params_local)
        return {"Q": P.eval_plan(nd.plan, dict(env_local), s)}

    CG.reset_trace_stats()
    runner, out, m = D.compile_distributed(fn, env, device_mesh_1d(1),
                                           cap_factor=16.0,
                                           params=defaults)
    assert rows_of(out["Q"], COLS) == want
    assert m["replicated_rows"] >= 0
    t0 = CG.TRACE_STATS.get("traces", 0)
    for keys in ([3, 9], [], [7, 1, 2]):
        out2, _ = runner(env, params={name: SK.pad_heavy(keys)})
        assert rows_of(out2["Q"], COLS) == want, keys
    assert CG.TRACE_STATS.get("traces", 0) == t0


def test_dist_duplicate_build_keys_general_join():
    """A non-unique build relation (general join stage) keeps exactly
    the cascade's multiplicity through the replicated round."""
    rng = np.random.RandomState(2)
    env = chain_env(seed=2)
    brows = [{"k": int(rng.randint(0, 16)), "w": float(rng.randint(1, 9))}
             for _ in range(24)]
    env["B"] = FlatBag.from_rows(brows, {"k": "int", "w": "real"},
                                 capacity=24)
    j1 = P.JoinP(P.ScanP("A", "a"), P.ScanP("B", "b"), ("a.k",),
                 ("b.k",), unique_right=False, expansion=4.0)
    j2 = P.JoinP(j1, P.ScanP("C", "c"), ("a.c",), ("c.c",))
    want = rows_of(P.eval_plan(j2, dict(env)), COLS)
    stats = chain_stats()
    stats["B"] = SK.TableStats(rows=24, distinct={"k": 16}, heavy={})
    g = P.build_program_graph([("Q", P.JoinP(
        P.JoinP(P.ScanP("A", "a"), P.ScanP("B", "b"), ("a.k",),
                ("b.k",), unique_right=False, expansion=4.0),
        P.ScanP("C", "c"), ("a.c",), ("c.c",)))], outputs=("Q",))
    assert P.apply_hypercube_program(g, stats, 8) == 1
    (nd,) = g.nodes
    assert rows_of(P.eval_plan(nd.plan, dict(env)), COLS) == want

    def fn(env_local, ctx, params_local):
        s = P.ExecSettings(dist=ctx, params=params_local)
        return {"Q": P.eval_plan(nd.plan, dict(env_local), s)}

    runner, out, _ = D.compile_distributed(fn, env, device_mesh_1d(1),
                                           cap_factor=16.0, params={})
    assert rows_of(out["Q"], COLS) == want


def test_replication_metrics_surface():
    """Satellite observability: the one-round exchange reports its
    replication factor and replicated bytes through the merged metrics."""
    env = chain_env(seed=1)
    g = _rewritten(chain_stats())
    (nd,) = g.nodes

    def fn(env_local, ctx, params_local):
        s = P.ExecSettings(dist=ctx, params=params_local)
        return {"Q": P.eval_plan(nd.plan, dict(env_local), s)}

    _, _, m = D.compile_distributed(fn, env, device_mesh_1d(1),
                                    cap_factor=16.0, params={})
    assert m["hypercube_exchanges"] == 1
    assert m["replication_factor_x100"] >= 100
    assert m["bytes_replicated"] >= 0
