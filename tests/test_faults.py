"""Fault-injection registry, typed-error policy, and the serving
runtime's recovery ladder (DESIGN.md "Fault model and recovery").

Fast tier: deterministic admission / deadline / retry / breaker /
manifest machinery on a virtual clock (no real sleeping), plus one
compiled family per service so each recovery rung is exercised by an
injected fault end to end.

Slow tier: a hypothesis differential property extending the PR 5
harness — for generated query specs, the answer served THROUGH a
recovery path (retry-after-transient, skip-disabled re-scan,
dist→single-device fallback) is bit-for-bit the answer of the
fault-free run."""

import os
import sys
import tempfile

import numpy as np
import pytest

try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_shim
    sys.modules["hypothesis"] = _hypothesis_shim
    sys.modules["hypothesis.strategies"] = _hypothesis_shim.strategies

from hypothesis import given, settings
from hypothesis import strategies as st  # noqa: F401

import test_differential as TD

from repro.core import codegen as CG
from repro.core import interpreter as I
from repro.core import nrc as N
from repro.errors import (AdmissionError, CapacityOverflowError,
                          ChunkCorruptionError, CircuitOpenError,
                          CompileError, DeadlineExceeded, ExchangeError,
                          FooterError, MissingChunkError, ReproError,
                          ShedError, StorageError)
from repro.faults import FAULTS, FaultRegistry
from repro.serve import (QueryRequest, QueryService, ServingRuntime)
from repro.serve.faults import CHAOS_CLASSES, arm_chaos_schedule
from repro.storage import StorageCatalog


@pytest.fixture(autouse=True)
def _clean_registry():
    FAULTS.reset()
    yield
    FAULTS.reset()


class VirtualClock:
    """Deterministic time for the runtime: ``sleep`` advances ``now``."""

    def __init__(self):
        self.t = 0.0
        self.slept = []

    def now(self):
        return self.t

    def sleep(self, s):
        self.slept.append(s)
        self.t += s


def make_runtime(svc, **kw):
    vc = VirtualClock()
    rt = ServingRuntime(svc, clock=vc.now, sleep=vc.sleep, seed=7, **kw)
    return rt, vc


SPEC = dict(seed=5, n_orders=8, n_parts=5, zipf=0.0,
            shape="flat_agg", sel="qty_ge", selc=2)


def prog_for(spec):
    return N.Program([N.Assignment("Q", TD.build_query(spec))])


@pytest.fixture(scope="module")
def served():
    """One compiled family on one local service (module-scoped so the
    fast tests share a single XLA compile)."""
    svc = QueryService(TD.TYPES, catalog=TD.CATALOG)
    env = svc.shred_inputs(TD.gen_inputs(SPEC))
    return svc, env


# ---------------------------------------------------------------------------
# typed errors
# ---------------------------------------------------------------------------

def test_error_hierarchy_and_transience():
    for cls in (StorageError, FooterError, ChunkCorruptionError,
                MissingChunkError, CompileError, ExchangeError,
                CapacityOverflowError, AdmissionError, ShedError,
                CircuitOpenError, DeadlineExceeded):
        assert issubclass(cls, ReproError)
    assert CompileError.transient and ExchangeError.transient \
        and CapacityOverflowError.transient
    assert not StorageError.transient and not ShedError.transient
    assert issubclass(ShedError, AdmissionError)
    assert issubclass(ChunkCorruptionError, StorageError)


# ---------------------------------------------------------------------------
# the registry
# ---------------------------------------------------------------------------

def test_registry_windows_match_and_determinism():
    reg = FaultRegistry(seed=3)
    reg.arm("s", "boom", first=2, count=2, part="A")
    fired = [bool(reg.hit("s", part=p))
             for p in ("A", "A", "A", "B", "A", "A")]
    # the window is call indices 2..3 of the SITE (call order is the
    # clock); ``match`` filters within it, it does not extend it
    assert fired == [False, False, True, False, False, False]
    assert reg.stats == {"s:boom": 1}
    # a probabilistic schedule replays identically under one seed
    seqs = []
    for _ in range(2):
        reg = FaultRegistry(seed=11)
        reg.arm("s", "maybe", first=0, count=-1, p=0.4)
        seqs.append([bool(reg.hit("s")) for _ in range(30)])
    assert seqs[0] == seqs[1] and 0 < sum(seqs[0]) < 30
    # disarmed registry is inert and cheap
    reg.disarm()
    assert not reg.enabled


def test_chaos_schedule_arms_every_class():
    arm_chaos_schedule(seed=1)
    assert {(r.site, r.kind) for r in FAULTS.rules} == set(CHAOS_CLASSES)


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------

def test_token_bucket_quota_sheds_and_refills(served):
    svc, env = served
    rt, vc = make_runtime(svc, tenant_rate=1.0, tenant_burst=2.0)
    reqs = [QueryRequest(prog_for(SPEC), env) for _ in range(3)]
    rs = [rt.submit(r) for r in reqs]
    assert [r.ok for r in rs] == [True, True, False]
    assert rs[2].shed and isinstance(rs[2].error, ShedError)
    assert rt.stats["shed_quota"] == 1
    vc.t += 1.0                          # one token refills
    assert rt.submit(QueryRequest(prog_for(SPEC), env)).ok
    # tenants are isolated: another tenant has its own bucket
    assert rt.submit(QueryRequest(prog_for(SPEC), env, tenant="b")).ok


def test_queue_depth_sheds_batch_tail(served):
    svc, env = served
    rt, _ = make_runtime(svc, max_queue=2)
    rs = rt.submit_many(
        [QueryRequest(prog_for(SPEC), env) for _ in range(4)])
    assert [r.ok for r in rs] == [True, True, False, False]
    assert all(r.shed for r in rs[2:])
    assert rt.stats["shed_queue"] == 2


def test_cold_compile_budget_sheds_new_families(served):
    svc, env = served                      # SPEC family already warm
    rt, _ = make_runtime(svc, compile_budget=0)
    assert rt.submit(QueryRequest(prog_for(SPEC), env)).ok   # warm: fine
    cold = dict(SPEC, shape="nested_map")
    r = rt.submit(QueryRequest(prog_for(cold), env))
    assert not r.ok and r.shed and isinstance(r.error, ShedError)
    assert rt.stats["shed_compile"] == 1


# ---------------------------------------------------------------------------
# deadlines, retries, breaker
# ---------------------------------------------------------------------------

def test_retry_clears_transient_compile_fault(served):
    svc, env = served
    svc.evict()                           # force a cold compile
    rt, vc = make_runtime(svc)
    FAULTS.arm("codegen.compile", "fail", first=0, count=1)
    r = rt.submit(QueryRequest(prog_for(SPEC), env))
    assert r.ok and r.retries == 1
    assert FAULTS.stats == {"codegen.compile:fail": 1}
    assert len(vc.slept) == 1             # one backoff sleep happened


def test_backoff_grows_exponentially_with_jitter(served):
    svc, env = served
    svc.evict()
    rt, vc = make_runtime(svc, max_retries=3, backoff_base=0.01,
                          backoff_cap=10.0)
    FAULTS.arm("codegen.compile", "fail", first=0, count=3)
    r = rt.submit(QueryRequest(prog_for(SPEC), env))
    assert r.ok and r.retries == 3
    s = vc.slept
    assert len(s) == 3
    # jittered into [0.5, 1.0] x base*2^(k-1): strictly growing windows
    for k, d in enumerate(s, start=1):
        lo, hi = 0.005 * 2 ** (k - 1), 0.01 * 2 ** (k - 1)
        assert lo <= d <= hi, (k, d)


def test_deadline_bounds_retries(served):
    svc, env = served
    svc.evict()
    rt, vc = make_runtime(svc, max_retries=50, backoff_base=0.1,
                          backoff_cap=0.1)
    FAULTS.arm("codegen.compile", "fail", first=0, count=-1)
    r = rt.submit(QueryRequest(prog_for(SPEC), env, deadline=0.25))
    assert not r.ok and isinstance(r.error, DeadlineExceeded)
    assert rt.stats["deadline_exceeded"] == 1
    assert vc.t <= 0.25 + 1e-9            # sleeps were deadline-clamped


def test_circuit_breaker_opens_and_probes(tmp_path, served):
    svc, env = served
    rt, vc = make_runtime(svc, max_retries=0, breaker_threshold=2,
                          breaker_cooldown=5.0)
    svc.evict()
    FAULTS.arm("codegen.compile", "fail", first=0, count=-1)
    for _ in range(2):                    # trip the breaker
        assert not rt.submit(QueryRequest(prog_for(SPEC), env)).ok
    r = rt.submit(QueryRequest(prog_for(SPEC), env))
    assert r.shed and isinstance(r.error, CircuitOpenError)
    assert rt.stats["circuit_open"] == 1
    # cooldown elapses; the fault is gone; the half-open probe closes it
    FAULTS.reset()
    vc.t += 5.0
    assert rt.submit(QueryRequest(prog_for(SPEC), env)).ok
    assert rt.submit(QueryRequest(prog_for(SPEC), env)).ok


# ---------------------------------------------------------------------------
# degradation: eviction mid-flight, stored re-scan
# ---------------------------------------------------------------------------

def test_injected_eviction_recompiles_transparently(served):
    svc, env = served
    rt, _ = make_runtime(svc)
    assert rt.submit(QueryRequest(prog_for(SPEC), env)).ok   # warm it
    miss0 = svc.stats["misses"]
    FAULTS.arm("serve.cache_evict", "evict", first=0, count=1)
    r = rt.submit(QueryRequest(prog_for(SPEC), env))
    assert r.ok and r.retries == 0
    assert rt.stats["injected_evictions"] == 1
    assert svc.stats["misses"] == miss0 + 1   # transparent recompile


def test_stored_chunk_fault_rescans_without_skipping(tmp_path):
    svc = QueryService(TD.TYPES, catalog=TD.CATALOG)
    cat = StorageCatalog(str(tmp_path))
    inputs = TD.gen_inputs(SPEC)
    cat.writer("d", TD.TYPES, chunk_rows=8).append(inputs)
    ds = cat.open("d")
    rt, _ = make_runtime(svc, verify_reads=True)
    ref = rt.submit(QueryRequest(prog_for(SPEC), ds))
    assert ref.ok
    FAULTS.arm("storage.chunk", "torn", first=0, count=1, arg=0.5)
    r = rt.submit(QueryRequest(prog_for(SPEC), ds))
    assert r.ok and "no_skip_rescan" in r.degraded
    assert rt.stats["degraded_no_skip"] == 1
    rows = svc.unshred_stored(prog_for(SPEC), ds, r.outputs, "Q")
    rows_ref = svc.unshred_stored(prog_for(SPEC), ds, ref.outputs, "Q")
    assert TD.equal(rows, rows_ref)
    # a PERSISTENT chunk fault fails the query, never the server
    FAULTS.reset()
    FAULTS.arm("storage.chunk", "missing", first=0, count=-1)
    r2 = rt.submit(QueryRequest(prog_for(SPEC), ds))
    assert not r2.ok and isinstance(r2.error, MissingChunkError)


def test_corrupt_encoded_blob_fails_typed_not_server(tmp_path):
    """On-disk corruption inside a compressed blob — real bytes, not an
    injected fault — must surface through the PR 6 CRC path: the
    no-skip rescan rung re-reads the same corrupt blob, the query fails
    with the typed error instead of serving silently wrong data, and
    the server keeps serving clean datasets."""
    import os
    from repro.storage.format import chunk_path
    svc = QueryService(TD.TYPES, catalog=TD.CATALOG)
    cat = StorageCatalog(str(tmp_path))
    spec = dict(SPEC, n_orders=40, sel=None)    # no pred: no skipping
    inputs = TD.gen_inputs(spec)
    cat.writer("d", TD.TYPES, chunk_rows=16).append(inputs)
    ds = cat.open("d")
    part = ds.parts["Ord__D_oparts"]
    i, col = next((i, col) for i, c in enumerate(part.meta.chunks)
                  for col in c.encodings)
    path = chunk_path(ds.dir, "Ord__D_oparts", col, i)
    with open(path, "r+b") as f:        # flip the blob's last byte
        f.seek(-1, os.SEEK_END)
        b = f.read(1)
        f.seek(-1, os.SEEK_END)
        f.write(bytes([b[0] ^ 0xFF]))
    rt, _ = make_runtime(svc, verify_reads=True)
    r = rt.submit(QueryRequest(prog_for(spec), ds))
    assert not r.ok and isinstance(r.error, ChunkCorruptionError)
    cat.writer("clean", TD.TYPES, chunk_rows=16).append(inputs)
    r2 = rt.submit(QueryRequest(prog_for(spec), cat.open("clean")))
    assert r2.ok


# ---------------------------------------------------------------------------
# crash-recoverable plan cache
# ---------------------------------------------------------------------------

def test_manifest_warm_replay_zero_retrace(tmp_path, served):
    svc, env = served
    man = str(tmp_path / "plans" / "manifest.json")
    rt, _ = make_runtime(svc, manifest_path=man)
    svc.evict()
    assert rt.submit(QueryRequest(prog_for(SPEC), env)).ok
    assert len(rt.manifest.entries) == 1
    # "restart": fresh service + runtime reading the same manifest
    svc2 = QueryService(TD.TYPES, catalog=TD.CATALOG)
    rt2, _ = make_runtime(svc2, manifest_path=man)
    assert rt2.warm_replay() == 1
    CG.reset_trace_stats()
    r = rt2.submit(QueryRequest(prog_for(SPEC), env))
    assert r.ok and CG.TRACE_STATS.get("traces", 0) == 0
    # replay is also parameter-generic: a different constant binding of
    # the same family stays zero-retrace
    r2 = rt2.submit(QueryRequest(prog_for(dict(SPEC, selc=3)), env))
    assert r2.ok and CG.TRACE_STATS.get("traces", 0) == 0
    rows = svc2.unshred(prog_for(SPEC), env, r.outputs, "Q")
    direct = I.eval_expr(TD.build_query(SPEC), TD.gen_inputs(SPEC))
    assert TD.equal(rows, direct)


def test_manifest_corruption_only_costs_cold_compiles(tmp_path, served):
    svc, env = served
    man = str(tmp_path / "manifest.json")
    with open(man, "w") as f:
        f.write("{torn")
    rt, _ = make_runtime(svc, manifest_path=man)
    assert rt.manifest.entries == {}
    assert rt.warm_replay() == 0
    assert rt.submit(QueryRequest(prog_for(SPEC), env)).ok


# ---------------------------------------------------------------------------
# batching
# ---------------------------------------------------------------------------

def test_submit_many_coalesces_one_family(served):
    svc, env = served
    rt, _ = make_runtime(svc)
    specs = [dict(SPEC, selc=c) for c in (1, 2, 3)]
    rs = rt.submit_many([QueryRequest(prog_for(s), env) for s in specs])
    assert all(r.ok for r in rs)
    assert rt.stats["batches"] == 1 and rt.stats["coalesced"] == 3
    for s, r in zip(specs, rs):
        rows = svc.unshred(prog_for(s), env, r.outputs, "Q")
        direct = I.eval_expr(TD.build_query(s), TD.gen_inputs(SPEC))
        assert TD.equal(rows, direct), s


def test_submit_never_raises(served):
    svc, env = served
    rt, _ = make_runtime(svc)
    bad = N.Program([N.Assignment("Q", N.Var("NoSuchInput",
                                             TD.ORD_T))])
    r = rt.submit(QueryRequest(bad, env))
    assert not r.ok and r.error is not None


# ---------------------------------------------------------------------------
# slow tier: hypothesis parity through every recovery path
# ---------------------------------------------------------------------------

def _runtime_stored(spec, tmpdir, **rt_kw):
    svc = QueryService(TD.TYPES, catalog=TD.CATALOG)
    cat = StorageCatalog(tmpdir)
    cat.writer("d", TD.TYPES, chunk_rows=8).append(TD.gen_inputs(spec))
    ds = cat.open("d")
    rt, _ = make_runtime(svc, **rt_kw)
    return rt, svc, ds


@pytest.mark.slow
@settings(max_examples=4, deadline=None)
@given(TD.spec_st())
def test_recovery_paths_bit_for_bit(spec):
    """Extends the PR 5 differential harness: the oracle answer, the
    answer after retry-on-transient-compile-fault, and the answer
    through the skip-disabled re-scan are all bit-for-bit equal."""
    FAULTS.reset()
    prog = N.Program([N.Assignment("Q", TD.build_query(spec))])
    inputs = TD.gen_inputs(spec)
    direct = I.eval_expr(TD.build_query(spec), inputs)
    with tempfile.TemporaryDirectory() as td:
        rt, svc, ds = _runtime_stored(spec, td, verify_reads=True)
        # path 1: retry after a transient compile fault (cold family)
        FAULTS.reset(0)
        FAULTS.arm("codegen.compile", "fail", first=0, count=1)
        r1 = rt.submit(QueryRequest(prog, ds))
        assert r1.ok and r1.retries == 1, (spec, r1.error)
        assert TD.equal(direct,
                        svc.unshred_stored(prog, ds, r1.outputs, "Q"))
        # path 2: torn chunk -> skip-disabled re-scan (warm family)
        FAULTS.reset(0)
        FAULTS.arm("storage.chunk", "torn", first=0, count=1, arg=0.5)
        r2 = rt.submit(QueryRequest(prog, ds))
        assert r2.ok and "no_skip_rescan" in r2.degraded, spec
        assert TD.equal(direct,
                        svc.unshred_stored(prog, ds, r2.outputs, "Q"))
        # path 3: mid-flight eviction -> transparent recompile
        FAULTS.reset(0)
        FAULTS.arm("serve.cache_evict", "evict", first=0, count=1)
        r3 = rt.submit(QueryRequest(prog, ds))
        assert r3.ok, spec
        assert TD.equal(direct,
                        svc.unshred_stored(prog, ds, r3.outputs, "Q"))
    FAULTS.reset()


@pytest.mark.slow
def test_dist_fallback_bit_for_bit():
    """Exchange failures on the distributed path degrade to the
    single-device twin with a bit-for-bit identical answer."""
    from repro.exec.dist import device_mesh_1d
    rng = np.random.RandomState(20260807)
    mesh = device_mesh_1d(1)
    for _ in range(2):
        spec = TD.random_spec(rng)
        prog = N.Program([N.Assignment("Q", TD.build_query(spec))])
        inputs = TD.gen_inputs(spec)
        direct = I.eval_expr(TD.build_query(spec), inputs)
        dsvc = QueryService(TD.TYPES, catalog=TD.CATALOG, mesh=mesh,
                            dist_kwargs=dict(adaptive=True))
        lsvc = QueryService(TD.TYPES, catalog=TD.CATALOG)
        env = dsvc.shred_inputs(inputs)
        vc = VirtualClock()
        rt = ServingRuntime(dsvc, local_fallback=lsvc, clock=vc.now,
                            sleep=vc.sleep, seed=1)
        FAULTS.reset(0)
        FAULTS.arm("dist.exchange", "fail", first=0, count=-1)
        r = rt.submit(QueryRequest(prog, env))
        FAULTS.reset()
        assert r.ok and "dist_to_local" in r.degraded, (spec, r.error)
        assert rt.stats["degraded_dist_local"] == 1
        assert TD.equal(direct,
                        lsvc.unshred(prog, env, r.outputs, "Q")), spec
