"""Differential property suite: hypothesis-generated small NRC
programs (maps, selects, equi-joins, sum_by, nesting; skewed and
uniform key draws) asserting parity across the four evaluation paths —

  1. the flat interpreter (``I.eval_expr``, the oracle),
  2. the whole-program local jit (``CG.jit_program``),
  3. distributed shard_map execution
     (``CG.compile_program_distributed``, 8 virtual devices), and
  4. storage-backed serving (``QueryService.execute_stored`` over a
     freshly persisted dataset, automatic skew decisions enabled),
  5. compressed storage (the same dataset written ``encoding="raw"``
     vs ``encoding="auto"`` — the codec layer must be invisible), and
  6. morsel-streamed out-of-core execution
     (``QueryService.execute_stored_streaming`` with tiny chunks and a
     tiny morsel budget).

Values are integer-valued floats, so float64 sums are exact in any
association order and the comparison is bit-for-bit (``bags_equal`` at
12 digits never rounds an exact value).

Runs under the real ``hypothesis`` when installed, else the
deterministic tier-1 shim (``_hypothesis_shim``)."""

import os
import subprocess
import sys
import tempfile

import numpy as np
import pytest

try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_shim
    sys.modules["hypothesis"] = _hypothesis_shim
    sys.modules["hypothesis.strategies"] = _hypothesis_shim.strategies

from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import codegen as CG
from repro.core import interpreter as I
from repro.core import materialization as M
from repro.core import nrc as N
from repro.core.unnesting import Catalog

PART_T = N.bag(N.tuple_t(pid=N.INT, pname=N.INT, price=N.REAL))
ORD_T = N.bag(N.tuple_t(
    odate=N.INT, oparts=N.bag(N.tuple_t(pid=N.INT, qty=N.REAL))))
TYPES = {"Ord": ORD_T, "Part": PART_T}
CATALOG = Catalog(unique_keys={"Part__F": ("pid",)})

SHAPES = ("nested_agg", "flat_agg", "nested_map", "nested_join_plain")
SELS = (None, "qty_ge", "pid_le")

# -- the 3-relation lane (hypercube multiway joins) -------------------------
SUPP_T = N.bag(N.tuple_t(sid=N.INT, sname=N.INT, fee=N.REAL))
ORD3_T = N.bag(N.tuple_t(
    odate=N.INT,
    oparts=N.bag(N.tuple_t(pid=N.INT, sid=N.INT, qty=N.REAL))))
TYPES3 = {"Ord": ORD3_T, "Part": PART_T, "Supp": SUPP_T}
CATALOG3 = Catalog(unique_keys={"Part__F": ("pid",),
                                "Supp__F": ("sid",)})
# the duplicate-supplier variant: Supp keys repeat, so the build side
# goes through general_join (every copy must match exactly once)
CATALOG3_DUP = Catalog(unique_keys={"Part__F": ("pid",)})
SHAPES3 = ("flat3_agg", "nested3_agg", "flat3_plain")


# ---------------------------------------------------------------------------
# case construction (plain data in, so the distributed subprocess can
# reproduce a case from its spec dict without hypothesis)
# ---------------------------------------------------------------------------

def gen_inputs(spec):
    rng = np.random.RandomState(spec["seed"])
    n_parts = spec["n_parts"]
    orders = []
    for i in range(spec["n_orders"]):
        items = []
        for _ in range(rng.randint(0, 6)):
            if spec["zipf"] > 0 and rng.rand() < spec["zipf"]:
                pid = 1 + (spec["seed"] % n_parts)   # one hot key
            else:
                pid = int(rng.randint(1, n_parts + 1))
            items.append({"pid": pid, "qty": float(rng.randint(1, 5))})
        orders.append({"odate": 20200100 + i, "oparts": items})
    parts = [{"pid": i, "pname": 100 + i, "price": float(i % 7 + 1)}
             for i in range(1, n_parts + 1)]
    return {"Ord": orders, "Part": parts}


def build_query(spec) -> N.Expr:
    Ord = N.Var("Ord", ORD_T)
    Part = N.Var("Part", PART_T)
    sel, selc = spec["sel"], spec["selc"]

    def guard(op, base):
        if sel == "qty_ge":
            return N.IfThen(op.qty.ge(N.Const(float(selc), N.REAL)), base)
        if sel == "pid_le":
            return N.IfThen(op.pid.le(N.Const(int(selc), N.INT)), base)
        return base

    def joined(op, body):
        return N.for_in("p", Part, lambda p:
            N.IfThen(op.pid.eq(p.pid), body(p)))

    shape = spec["shape"]
    if shape == "nested_agg":
        def tops(x):
            inner = N.for_in("op", x.oparts, lambda op: guard(op,
                joined(op, lambda p: N.Singleton(N.record(
                    pname=p.pname, total=op.qty * p.price)))))
            return N.SumBy(inner, keys=("pname",), values=("total",))
        return N.for_in("x", Ord, lambda x: N.Singleton(N.record(
            odate=x.odate, tops=tops(x))))
    if shape == "flat_agg":
        inner = N.for_in("x", Ord, lambda x:
            N.for_in("op", x.oparts, lambda op: guard(op,
                joined(op, lambda p: N.Singleton(N.record(
                    odate=x.odate, total=op.qty * p.price))))))
        return N.SumBy(inner, keys=("odate",), values=("total",))
    if shape == "nested_map":
        return N.for_in("x", Ord, lambda x: N.Singleton(N.record(
            odate=x.odate,
            items=N.for_in("op", x.oparts, lambda op: guard(op,
                N.Singleton(N.record(pid2=op.pid + N.Const(3, N.INT),
                                     q=op.qty)))))))
    assert shape == "nested_join_plain", shape
    return N.for_in("x", Ord, lambda x: N.Singleton(N.record(
        odate=x.odate,
        items=N.for_in("op", x.oparts, lambda op: guard(op,
            joined(op, lambda p: N.Singleton(N.record(
                pname=p.pname, s=op.qty * p.price))))))))


def random_spec(rng) -> dict:
    sel = SELS[int(rng.randint(0, len(SELS)))]
    return dict(seed=int(rng.randint(0, 10000)),
                n_orders=int(rng.randint(3, 12)),
                n_parts=int(rng.randint(4, 10)),
                zipf=float([0.0, 0.5, 0.9][int(rng.randint(0, 3))]),
                shape=SHAPES[int(rng.randint(0, len(SHAPES)))],
                sel=sel, selc=int(rng.randint(1, 4)))


def spec_st():
    return st.composite(
        lambda draw: dict(
            seed=draw(st.integers(0, 10000)),
            n_orders=draw(st.integers(3, 12)),
            n_parts=draw(st.integers(4, 10)),
            zipf=draw(st.sampled_from([0.0, 0.5, 0.9])),
            shape=draw(st.sampled_from(SHAPES)),
            sel=draw(st.sampled_from(SELS)),
            selc=draw(st.integers(1, 4))))()


def gen_inputs3(spec):
    """Plain-data inputs for the 3-relation chain. ``n_supp`` may be 1
    (one tiny relation); ``dup_supp`` doubles every supplier key with a
    different fee so the Supp build side is non-unique."""
    rng = np.random.RandomState(spec["seed"])
    n_parts, n_supp = spec["n_parts"], spec["n_supp"]
    orders = []
    for i in range(spec["n_orders"]):
        items = []
        for _ in range(rng.randint(0, 6)):
            if spec["zipf"] > 0 and rng.rand() < spec["zipf"]:
                pid = 1 + (spec["seed"] % n_parts)   # one hot key
            else:
                pid = int(rng.randint(1, n_parts + 1))
            items.append({"pid": pid,
                          "sid": int(rng.randint(1, n_supp + 1)),
                          "qty": float(rng.randint(1, 5))})
        orders.append({"odate": 20200100 + i, "oparts": items})
    parts = [{"pid": i, "pname": 100 + i, "price": float(i % 7 + 1)}
             for i in range(1, n_parts + 1)]
    supps = [{"sid": i, "sname": 200 + i, "fee": float(i % 5 + 1)}
             for i in range(1, n_supp + 1)]
    if spec["dup_supp"]:
        supps += [{"sid": i, "sname": 300 + i, "fee": float(i % 3 + 1)}
                  for i in range(1, n_supp + 1)]
    return {"Ord": orders, "Part": parts, "Supp": supps}


def build_query3(spec) -> N.Expr:
    """Ord.oparts joins Part on pid AND Supp on sid — a 3-relation
    equi-join chain sharing the oparts spine (the hypercube shape)."""
    Ord = N.Var("Ord", ORD3_T)
    Part = N.Var("Part", PART_T)
    Supp = N.Var("Supp", SUPP_T)

    def chain(op, body):
        return N.for_in("p", Part, lambda p:
            N.IfThen(op.pid.eq(p.pid),
                N.for_in("s", Supp, lambda s:
                    N.IfThen(op.sid.eq(s.sid), body(p, s)))))

    shape = spec["shape"]
    if shape == "flat3_agg":
        inner = N.for_in("x", Ord, lambda x:
            N.for_in("op", x.oparts, lambda op:
                chain(op, lambda p, s: N.Singleton(N.record(
                    odate=x.odate, total=op.qty * p.price + s.fee)))))
        return N.SumBy(inner, keys=("odate",), values=("total",))
    if shape == "nested3_agg":
        def tops(x):
            inner = N.for_in("op", x.oparts, lambda op:
                chain(op, lambda p, s: N.Singleton(N.record(
                    pname=p.pname, total=op.qty * p.price + s.fee))))
            return N.SumBy(inner, keys=("pname",), values=("total",))
        return N.for_in("x", Ord, lambda x: N.Singleton(N.record(
            odate=x.odate, tops=tops(x))))
    assert shape == "flat3_plain", shape
    return N.for_in("x", Ord, lambda x: N.Singleton(N.record(
        odate=x.odate,
        items=N.for_in("op", x.oparts, lambda op:
            chain(op, lambda p, s: N.Singleton(N.record(
                pname=p.pname, sname=s.sname,
                v=op.qty * p.price + s.fee)))))))


def catalog3(spec) -> Catalog:
    return CATALOG3_DUP if spec["dup_supp"] else CATALOG3


def random_spec3(rng) -> dict:
    # random.Random.randint is INCLUSIVE on both ends — the old
    # ``[...][rng.randint(0, 3)]`` subscripts crashed on ~1/4 of seeds
    return dict(seed=int(rng.randint(0, 10000)),
                n_orders=int(rng.randint(3, 12)),
                n_parts=int(rng.randint(4, 10)),
                n_supp=int(rng.choice([1, 3, 8])),
                zipf=float(rng.choice([0.0, 0.5, 0.9])),
                shape=rng.choice(SHAPES3),
                dup_supp=bool(rng.randint(0, 1)))


def spec3_st():
    return st.composite(
        lambda draw: dict(
            seed=draw(st.integers(0, 10000)),
            n_orders=draw(st.integers(3, 12)),
            n_parts=draw(st.integers(4, 10)),
            n_supp=draw(st.sampled_from([1, 3, 8])),
            zipf=draw(st.sampled_from([0.0, 0.5, 0.9])),
            shape=draw(st.sampled_from(SHAPES3)),
            dup_supp=draw(st.sampled_from([False, True]))))()


def equal(a, b) -> bool:
    return I.bags_equal(a, b, float_digits=12)


# -- evaluation paths -------------------------------------------------------

def run_jit(q, inputs, types=TYPES, catalog=CATALOG):
    prog = N.Program([N.Assignment("Q", q)])
    sp = M.shred_program(prog, types, domain_elimination=True)
    cp = CG.compile_program(sp, catalog)
    env = CG.columnar_shred_inputs(inputs, types)
    out = CG.jit_program(cp)(env)
    man = sp.manifests["Q"]
    parts = {(): out[man.top], **{p: out[n]
                                  for p, n in man.dicts.items()}}
    return CG.parts_to_rows(parts, q.ty)


def run_jit_cost(q, inputs, cost_mode, types=TYPES3, catalog=CATALOG3,
                 stats=None):
    """Local jit with the cost-based optimizer toggled: same program,
    same inputs, ``cost_mode="auto"`` may reorder join chains, flip the
    hypercube gate, and keep fusions the rule-based pass would break —
    the results must stay bit-for-bit identical."""
    prog = N.Program([N.Assignment("Q", q)])
    sp = M.shred_program(prog, types, domain_elimination=True)
    cp = CG.compile_program(sp, catalog, skew_stats=stats,
                            skew_partitions=8, cost_mode=cost_mode)
    env = CG.columnar_shred_inputs(inputs, types)
    out = CG.jit_program(cp)(env)
    man = sp.manifests["Q"]
    parts = {(): out[man.top], **{p: out[n]
                                  for p, n in man.dicts.items()}}
    return CG.parts_to_rows(parts, q.ty)


def run_stored(q, inputs, tmpdir, encoding="auto", types=TYPES,
               catalog=CATALOG, cost_mode="off"):
    from repro.serve import QueryService
    from repro.storage import StorageCatalog
    cat = StorageCatalog(tmpdir)
    w = cat.writer("d_" + encoding, types, chunk_rows=16,
                   encoding=encoding)
    w.append(inputs)
    ds = cat.open("d_" + encoding)
    # skew_partitions=8: automatic SkewJoinP decisions exercise the
    # whole compile path even though local evaluation is placement-free
    svc = QueryService(types, catalog=catalog, skew_partitions=8,
                       cost_mode=cost_mode)
    prog = N.Program([N.Assignment("Q", q)])
    out = svc.execute_stored(prog, ds)
    return svc.unshred_stored(prog, ds, out, "Q")


def run_stored_streamed(q, inputs, tmpdir):
    """Morsel-streamed lane: tiny chunks + a tiny morsel budget force a
    multi-morsel stream whenever the dataset allows it. Returns None
    when the program/dataset pair deterministically refuses to stream
    (StreamingUnsupportedError) — the caller then only checks the
    documented fallback contract."""
    from repro.errors import StreamingUnsupportedError
    from repro.serve import QueryService
    from repro.storage import StorageCatalog
    cat = StorageCatalog(tmpdir)
    w = cat.writer("dm", TYPES, chunk_rows=4)
    w.append(inputs)
    ds = cat.open("dm")
    svc = QueryService(TYPES, catalog=CATALOG)
    prog = N.Program([N.Assignment("Q", q)])
    try:
        out = svc.execute_stored_streaming(prog, ds, morsel_rows=4,
                                           root="Ord")
    except StreamingUnsupportedError:
        return None
    return svc.unshred_stored(prog, ds, out, "Q")


# ---------------------------------------------------------------------------
# fast tier: interpreter vs local jit
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(spec_st())
def test_differential_interpreter_vs_jit(spec):
    q = build_query(spec)
    inputs = gen_inputs(spec)
    direct = I.eval_expr(q, inputs)
    assert equal(direct, run_jit(q, inputs)), spec


@settings(max_examples=6, deadline=None)
@given(spec3_st())
def test_differential3_interpreter_vs_jit(spec):
    q = build_query3(spec)
    inputs = gen_inputs3(spec)
    direct = I.eval_expr(q, inputs)
    assert equal(direct, run_jit(q, inputs, TYPES3, catalog3(spec))), \
        spec


# ---------------------------------------------------------------------------
# second tier: storage-backed serving
# ---------------------------------------------------------------------------

@pytest.mark.slow
@settings(max_examples=6, deadline=None)
@given(spec_st())
def test_differential_stored(spec):
    q = build_query(spec)
    inputs = gen_inputs(spec)
    direct = I.eval_expr(q, inputs)
    with tempfile.TemporaryDirectory() as td:
        assert equal(direct, run_stored(q, inputs, td)), spec


@pytest.mark.slow
@settings(max_examples=4, deadline=None)
@given(spec3_st())
def test_differential3_stored(spec):
    q = build_query3(spec)
    inputs = gen_inputs3(spec)
    direct = I.eval_expr(q, inputs)
    with tempfile.TemporaryDirectory() as td:
        assert equal(direct, run_stored(q, inputs, td, types=TYPES3,
                                        catalog=catalog3(spec))), spec


# ---------------------------------------------------------------------------
# second tier: compressed storage and morsel streaming
# ---------------------------------------------------------------------------

@pytest.mark.slow
@settings(max_examples=6, deadline=None)
@given(spec_st())
def test_differential_compressed_storage(spec):
    """raw-written and auto-encoded datasets must serve identical
    results: compression is a storage-layer concern that query
    execution can never observe."""
    q = build_query(spec)
    inputs = gen_inputs(spec)
    direct = I.eval_expr(q, inputs)
    with tempfile.TemporaryDirectory() as td:
        raw = run_stored(q, inputs, td, encoding="raw")
        enc = run_stored(q, inputs, td, encoding="auto")
        assert equal(direct, raw), ("raw", spec)
        assert equal(direct, enc), ("auto", spec)


@pytest.mark.slow
@settings(max_examples=6, deadline=None)
@given(spec_st())
def test_differential_morsel_streamed(spec):
    q = build_query(spec)
    inputs = gen_inputs(spec)
    direct = I.eval_expr(q, inputs)
    with tempfile.TemporaryDirectory() as td:
        streamed = run_stored_streamed(q, inputs, td)
    if streamed is None:
        # the plan refused to stream; the one-shot path must still work
        with tempfile.TemporaryDirectory() as td:
            assert equal(direct, run_stored(q, inputs, td)), spec
    else:
        assert equal(direct, streamed), spec


# ---------------------------------------------------------------------------
# second tier: all four paths on 8 virtual devices (one subprocess
# loops the examples, per the dry-run isolation rule)
# ---------------------------------------------------------------------------

_DIST_CHILD = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, tempfile
sys.path.insert(0, %(src)r)
sys.path.insert(0, %(tests)r)
import numpy as np
import repro
from repro.core import codegen as CG
from repro.core import interpreter as I
from repro.core import materialization as M
from repro.core import nrc as N
from repro.exec.dist import device_mesh_1d
from repro.storage import StorageCatalog, table_stats
import test_differential as TD

mesh = device_mesh_1d(8)
rng = np.random.RandomState(20260731)
for case in range(%(examples)d):
    spec = TD.random_spec(rng)
    q = TD.build_query(spec)
    inputs = TD.gen_inputs(spec)
    direct = I.eval_expr(q, inputs)
    assert TD.equal(direct, TD.run_jit(q, inputs)), ("jit", spec)
    with tempfile.TemporaryDirectory() as td:
        assert TD.equal(direct, TD.run_stored(q, inputs, td)), \\
            ("stored", spec)
        # distributed: compile with storage-derived skew statistics so
        # skewed draws actually lower through SkewJoinP on the wire
        cat = StorageCatalog(td)
        w = cat.writer("d8", TD.TYPES, chunk_rows=16)
        w.append(inputs)
        ds = cat.open("d8")
        prog = N.Program([N.Assignment("Q", q)])
        sp = M.shred_program(prog, TD.TYPES, domain_elimination=True)
        cp = CG.compile_program(sp, TD.CATALOG,
                                skew_stats=table_stats(ds),
                                skew_partitions=8)
        env = CG.columnar_shred_inputs(inputs, TD.TYPES)
        env = {k: b.resize(((b.capacity + 7) // 8) * 8)
               for k, b in env.items()}
        runner, out, metrics = CG.compile_program_distributed(
            cp, env, mesh, cap_factor=16.0)
        man = sp.manifests["Q"]
        parts = {(): out[man.top],
                 **{p: out[n] for p, n in man.dicts.items()}}
        assert TD.equal(direct, CG.parts_to_rows(parts, q.ty)), \\
            ("dist", spec)
print("OK %(examples)d cases")
"""


@pytest.mark.slow
def test_differential_distributed_four_paths():
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    script = _DIST_CHILD % {"src": os.path.abspath(src),
                            "tests": os.path.dirname(
                                os.path.abspath(__file__)),
                            "examples": 5}
    res = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, timeout=1200)
    assert res.returncode == 0, \
        f"STDOUT:{res.stdout}\nSTDERR:{res.stderr}"
    assert "OK" in res.stdout


# ---------------------------------------------------------------------------
# second tier: the 3-relation hypercube lane — interpreter vs jit vs
# storage-backed vs distributed, including degenerate meshes (P=1, a
# prime share budget P=3 executed on a 1-device mesh, and one tiny
# relation via n_supp=1) — one subprocess loops all cases
# ---------------------------------------------------------------------------

_DIST3_CHILD = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, tempfile
sys.path.insert(0, %(src)r)
sys.path.insert(0, %(tests)r)
import numpy as np
import repro
from repro.core import codegen as CG
from repro.core import interpreter as I
from repro.core import materialization as M
from repro.core import nrc as N
from repro.core.plans import MultiJoinP, _walk_plan
from repro.exec.dist import device_mesh_1d
from repro.storage import StorageCatalog, table_stats
import test_differential as TD

# (share budget, mesh size): the full 8-way hypercube, a PRIME budget
# folded onto a single device, and the fully degenerate P=1
CONFIGS = ((8, 8), (3, 1), (1, 1))
meshes = {p: device_mesh_1d(p) for p in {m for _, m in CONFIGS}}
rng = np.random.RandomState(20260807)
multijoins_at_8 = 0
for case in range(%(examples)d):
    spec = TD.random_spec3(rng)
    q = TD.build_query3(spec)
    inputs = TD.gen_inputs3(spec)
    cat3 = TD.catalog3(spec)
    direct = I.eval_expr(q, inputs)
    assert TD.equal(direct, TD.run_jit(q, inputs, TD.TYPES3, cat3)), \\
        ("jit", spec)
    with tempfile.TemporaryDirectory() as td:
        assert TD.equal(direct, TD.run_stored(
            q, inputs, td, types=TD.TYPES3, catalog=cat3)), \\
            ("stored", spec)
        # distributed: storage-derived statistics drive both the skew
        # pass and the hypercube share planner
        cat = StorageCatalog(td)
        w = cat.writer("d8", TD.TYPES3, chunk_rows=16)
        w.append(inputs)
        ds = cat.open("d8")
        prog = N.Program([N.Assignment("Q", q)])
        sp = M.shred_program(prog, TD.TYPES3, domain_elimination=True)
        env0 = CG.columnar_shred_inputs(inputs, TD.TYPES3)
        man = sp.manifests["Q"]
        for budget, psize in CONFIGS:
            cp = CG.compile_program(sp, cat3,
                                    skew_stats=table_stats(ds),
                                    skew_partitions=budget)
            mj = sum(1 for _, p in cp.plans for s in _walk_plan(p)
                     if isinstance(s, MultiJoinP))
            if budget == 8:
                multijoins_at_8 += mj
            env = {k: b.resize(((b.capacity + 7) // 8) * 8)
                   for k, b in env0.items()}
            runner, out, metrics = CG.compile_program_distributed(
                cp, env, meshes[psize], cap_factor=16.0)
            parts = {(): out[man.top],
                     **{p: out[n] for p, n in man.dicts.items()}}
            assert TD.equal(direct, CG.parts_to_rows(parts, q.ty)), \\
                ("dist", budget, psize, spec)
# the sweep must actually exercise the one-round plan, not just
# cascades that happened to pass
assert multijoins_at_8 >= 1, "no case lowered through MultiJoinP"
print("OK %(examples)d cases, multijoins_at_8=" + str(multijoins_at_8))
"""


@pytest.mark.slow
def test_differential3_hypercube_distributed():
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    script = _DIST3_CHILD % {"src": os.path.abspath(src),
                             "tests": os.path.dirname(
                                 os.path.abspath(__file__)),
                             "examples": 4}
    res = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, timeout=1800)
    assert res.returncode == 0, \
        f"STDOUT:{res.stdout}\nSTDERR:{res.stderr}"
    assert "OK" in res.stdout


# ---------------------------------------------------------------------------
# cost-based optimizer parity: cost_mode="auto" must never change a
# result, only the plan (join order / exchange strategy / fusion)
# ---------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(spec3_st())
def test_differential3_cost_auto_vs_off(spec):
    """Without statistics the estimator runs on defaults: the reorder
    pass must keep the program order (ties keep identity) and parity is
    bit-for-bit."""
    q = build_query3(spec)
    inputs = gen_inputs3(spec)
    cat = catalog3(spec)
    direct = I.eval_expr(q, inputs)
    off = run_jit_cost(q, inputs, "off", catalog=cat)
    auto = run_jit_cost(q, inputs, "auto", catalog=cat)
    assert equal(direct, off), ("off", spec)
    assert equal(direct, auto), ("auto", spec)


@pytest.mark.slow
@settings(max_examples=4, deadline=None)
@given(spec3_st())
def test_differential3_cost_auto_vs_off_with_stats(spec):
    """With storage-derived statistics the costed passes actually make
    decisions (reorder, cascade-vs-hypercube, keep-vs-break fusion);
    results must still match the interpreter exactly."""
    from repro.storage import StorageCatalog, table_stats
    q = build_query3(spec)
    inputs = gen_inputs3(spec)
    cat = catalog3(spec)
    direct = I.eval_expr(q, inputs)
    with tempfile.TemporaryDirectory() as td:
        scat = StorageCatalog(td)
        w = scat.writer("dc", TYPES3, chunk_rows=16)
        w.append(inputs)
        stats = table_stats(scat.open("dc"))
    assert equal(direct, run_jit_cost(q, inputs, "off", catalog=cat,
                                      stats=stats)), ("off", spec)
    assert equal(direct, run_jit_cost(q, inputs, "auto", catalog=cat,
                                      stats=stats)), ("auto", spec)


@pytest.mark.slow
@settings(max_examples=4, deadline=None)
@given(spec3_st())
def test_differential3_stored_cost_auto(spec):
    """Storage-backed serving with ``cost_mode="auto"``: the service
    derives stats from the dataset, the costed compile runs end to end,
    and the unshredded result matches the oracle."""
    q = build_query3(spec)
    inputs = gen_inputs3(spec)
    direct = I.eval_expr(q, inputs)
    with tempfile.TemporaryDirectory() as td:
        assert equal(direct, run_stored(q, inputs, td, types=TYPES3,
                                        catalog=catalog3(spec),
                                        cost_mode="auto")), spec


_COST_DIST_CHILD = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, tempfile
sys.path.insert(0, %(src)r)
sys.path.insert(0, %(tests)r)
import numpy as np
import repro
from repro.core import codegen as CG
from repro.core import interpreter as I
from repro.core import materialization as M
from repro.core import nrc as N
from repro.exec.dist import device_mesh_1d
from repro.storage import StorageCatalog, table_stats
import test_differential as TD

mesh = device_mesh_1d(8)
rng = np.random.RandomState(20260808)
for case in range(%(examples)d):
    spec = TD.random_spec3(rng)
    q = TD.build_query3(spec)
    inputs = TD.gen_inputs3(spec)
    cat3 = TD.catalog3(spec)
    direct = I.eval_expr(q, inputs)
    with tempfile.TemporaryDirectory() as td:
        cat = StorageCatalog(td)
        w = cat.writer("dc", TD.TYPES3, chunk_rows=16)
        w.append(inputs)
        stats = table_stats(cat.open("dc"))
    prog = N.Program([N.Assignment("Q", q)])
    sp = M.shred_program(prog, TD.TYPES3, domain_elimination=True)
    env0 = CG.columnar_shred_inputs(inputs, TD.TYPES3)
    man = sp.manifests["Q"]
    for mode in ("off", "auto"):
        cp = CG.compile_program(sp, cat3, skew_stats=stats,
                                skew_partitions=8, cost_mode=mode)
        env = {k: b.resize(((b.capacity + 7) // 8) * 8)
               for k, b in env0.items()}
        runner, out, metrics = CG.compile_program_distributed(
            cp, env, mesh, cap_factor=16.0)
        parts = {(): out[man.top],
                 **{p: out[n] for p, n in man.dicts.items()}}
        assert TD.equal(direct, CG.parts_to_rows(parts, q.ty)), \\
            ("dist-cost", mode, spec)
print("OK %(examples)d cases")
"""


@pytest.mark.slow
def test_differential3_cost_distributed():
    """8-virtual-device parity: the same statistics-driven compile,
    cost off vs auto, executed through shard_map."""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    script = _COST_DIST_CHILD % {"src": os.path.abspath(src),
                                 "tests": os.path.dirname(
                                     os.path.abspath(__file__)),
                                 "examples": 3}
    res = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, timeout=1800)
    assert res.returncode == 0, \
        f"STDOUT:{res.stdout}\nSTDERR:{res.stderr}"
    assert "OK" in res.stdout
