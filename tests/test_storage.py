"""Shredded columnar storage engine: write -> reopen round trip
(bit-for-bit), streaming-append label continuity, strict string-encoder
vocabulary persistence, zone-map chunk skipping + column pruning
counters, and query parity over persisted datasets via both
``run_flat_program`` (lazy StorageEnv) and ``QueryService.execute_stored``
(bind-time predicate resolution, zero warm retracing)."""

import numpy as np
import pytest

from repro.columnar.table import StringEncoder
from repro.core import codegen as CG
from repro.core import materialization as M
from repro.core import nrc as N
from repro.core.unnesting import Catalog
from repro.serve import QueryService
from repro.storage import (STORAGE_STATS, StorageCatalog,
                           reset_storage_stats, storage_requirements)

PART_T = N.bag(N.tuple_t(pid=N.INT, pname=N.INT, price=N.REAL,
                         mfgr=N.INT))
ORD_T = N.bag(N.tuple_t(
    odate=N.INT,
    oparts=N.bag(N.tuple_t(pid=N.INT, qty=N.REAL, note=N.INT))))
INPUT_TYPES = {"Ord": ORD_T, "Part": PART_T}
CATALOG = Catalog(unique_keys={"Part__F": ("pid",)})


def family(min_price: float) -> N.Program:
    Part = N.Var("Part", PART_T)
    Ord = N.Var("Ord", ORD_T)

    def tops(x):
        inner = N.for_in("op", x.oparts, lambda op:
            N.for_in("p", Part, lambda p:
                N.IfThen(N.BoolOp("&&", op.pid.eq(p.pid),
                                  p.price.ge(N.Const(min_price, N.REAL))),
                         N.Singleton(N.record(pname=p.pname,
                                              total=op.qty * p.price)))))
        return N.SumBy(inner, keys=("pname",), values=("total",))

    q = N.for_in("x", Ord, lambda x: N.Singleton(N.record(
        odate=x.odate, tops=tops(x))))
    return N.Program([N.Assignment("Q", q)])


def gen_data(n_orders=50, n_parts=64, seed=0):
    rng = np.random.RandomState(seed)
    orders = [{"odate": 20200000 + i,
               "oparts": [{"pid": int(rng.randint(1, n_parts + 1)),
                           "qty": float(rng.randint(1, 5)), "note": 7}
                          for _ in range(rng.randint(0, 5))]}
              for i in range(n_orders)]
    # prices equal pid: consecutive chunks carry disjoint price ranges,
    # so a selective price predicate provably skips chunks
    parts = [{"pid": i, "pname": 100 + i, "price": float(i),
              "mfgr": i % 5} for i in range(1, n_parts + 1)]
    return {"Ord": orders, "Part": parts}


@pytest.fixture(scope="module")
def data():
    return gen_data()


@pytest.fixture(scope="module")
def dataset(data, tmp_path_factory):
    cat = StorageCatalog(str(tmp_path_factory.mktemp("store")))
    return cat.write("shop", data, INPUT_TYPES, chunk_rows=16)


def norm(rows):
    return sorted(
        (r["odate"], tuple(sorted((t["pname"], round(t["total"], 6))
                                  for t in r["tops"])))
        for r in rows)


# ---------------------------------------------------------------------------
# round trip
# ---------------------------------------------------------------------------

def test_roundtrip_bit_for_bit(data, dataset):
    env_mem = CG.columnar_shred_inputs(data, INPUT_TYPES)
    env_disk = dataset.load_env()
    assert set(env_mem) == set(env_disk)
    for name, bag in env_mem.items():
        got = env_disk[name]
        assert bag.columns == got.columns
        assert bag.capacity == got.capacity
        for c in bag.data:
            assert np.array_equal(np.asarray(bag.data[c]),
                                  np.asarray(got.data[c])), (name, c)
        assert np.array_equal(np.asarray(bag.valid),
                              np.asarray(got.valid)), name


def test_streaming_append_matches_one_shot(data, tmp_path):
    """N appended batches == one-shot shred, labels included (the
    label-base continuation contract)."""
    cat = StorageCatalog(str(tmp_path))
    w = cat.writer("stream", INPUT_TYPES, chunk_rows=16)
    orders = data["Ord"]
    w.append({"Ord": orders[:20], "Part": data["Part"]})
    w.append({"Ord": orders[20:35]})
    w.append({"Ord": orders[35:]})
    env_mem = CG.columnar_shred_inputs(data, INPUT_TYPES)
    env_disk = cat.open("stream").load_env()
    for name, bag in env_mem.items():
        got = env_disk[name]
        for c in bag.data:
            assert np.array_equal(np.asarray(bag.data[c]),
                                  np.asarray(got.data[c])), (name, c)


def test_writer_resume_continues_and_fresh_overwrites(data, tmp_path):
    """resume=True reopens a dataset for continued streaming (labels
    carry on exactly); a fresh writer wipes stale chunks instead of
    shadowing them."""
    cat = StorageCatalog(str(tmp_path))
    orders = data["Ord"]
    w = cat.writer("grow", INPUT_TYPES, chunk_rows=16)
    w.append({"Ord": orders[:20], "Part": data["Part"]})
    # simulate a process restart: a NEW writer resumes the footer state
    w2 = cat.writer("grow", INPUT_TYPES, chunk_rows=16, resume=True)
    w2.append({"Ord": orders[20:]})
    env_mem = CG.columnar_shred_inputs(data, INPUT_TYPES)
    env_disk = cat.open("grow").load_env()
    for name, bag in env_mem.items():
        for c in bag.data:
            assert np.array_equal(np.asarray(bag.data[c]),
                                  np.asarray(env_disk[name].data[c])), \
                (name, c)
    # fresh (non-resume) writer on the same name starts over: no stale
    # rows or orphan chunks survive
    w3 = cat.writer("grow", INPUT_TYPES, chunk_rows=16)
    w3.append({"Ord": orders[:5], "Part": data["Part"][:3]})
    ds3 = cat.open("grow", refresh=True)
    assert ds3.parts["Ord__F"].rows == 5
    assert ds3.parts["Part__F"].rows == 3
    assert ds3.parts["Ord__F"].n_chunks == 1


def test_footer_survives_reopen(dataset):
    ds2 = StorageCatalog(dataset.dir.rsplit("/", 1)[0]).open(
        "shop", refresh=True)
    assert ds2.fingerprint() == dataset.fingerprint()
    pm = ds2.parts["Part__F"].meta
    assert pm.schema["price"] == "real"
    assert pm.chunks and all(c.rows <= 16 for c in pm.chunks)
    z = pm.chunks[0].zones["price"]
    assert z["lo"] == 1.0 and z["hi"] == 16.0 and z["distinct"] == 16


# ---------------------------------------------------------------------------
# strict string encoders
# ---------------------------------------------------------------------------

STR_T = N.bag(N.tuple_t(k=N.INT, city=N.STRING))


def test_encoder_vocab_roundtrip_and_strict(tmp_path):
    rows = [{"k": i, "city": c} for i, c in
            enumerate(["lyon", "oslo", "kobe", "lyon", "oslo"])]
    cat = StorageCatalog(str(tmp_path))
    enc = {}
    w = cat.writer("cities", {"R": STR_T}, chunk_rows=2, encoders=enc)
    w.write({"R": rows})
    ds = cat.open("cities")
    # vocabulary persisted exactly
    assert ds.encoders["city"].rev == enc["city"].rev == \
        ["lyon", "oslo", "kobe"]
    bag = ds.parts["R__F"].load()
    decoded = [r["city"] for r in bag.to_rows(decoders=ds.encoders)]
    assert decoded == ["lyon", "oslo", "kobe", "lyon", "oslo"]
    # strict mode: out-of-range code raises instead of fabricating
    with pytest.raises(KeyError):
        ds.encoders["city"].decode(99)
    with pytest.raises(KeyError):
        ds.encoders["city"].encode("quito")
    # the default encoder still fabricates (display fallback)
    assert StringEncoder().decode(99) == "<99>"


# ---------------------------------------------------------------------------
# requirements extraction + zone-map skipping
# ---------------------------------------------------------------------------

def compile_family(min_price):
    sp = M.shred_program(family(min_price), INPUT_TYPES,
                         domain_elimination=True)
    return sp, CG.compile_program(sp, CATALOG)


def test_storage_requirements(dataset):
    _, cp = compile_family(40.0)
    req = storage_requirements(cp, set(dataset.parts))
    assert req["Part__F"].columns == {"pid", "pname", "price"}
    assert req["Ord__D_oparts"].columns == {"label", "pid", "qty"}
    assert req["Ord__F"].columns == {"odate", "oparts"}
    # only the Part side has a pushed-down row-local predicate
    assert req["Part__F"].pred is not None
    assert req["Ord__F"].pred is None
    assert col_set(req["Part__F"].pred) == {"price"}


def col_set(pred):
    from repro.core.plans import col_expr_deps
    return col_expr_deps(pred)


def test_zone_map_selects_fewer_chunks(dataset):
    from repro.serve.query_service import lift_program
    lifted, _ = lift_program(family(0.0))
    sp = M.shred_program(lifted, INPUT_TYPES, domain_elimination=True)
    cp = CG.compile_program(sp, CATALOG)
    req = storage_requirements(cp, set(dataset.parts))
    part = dataset.parts["Part__F"]
    all_chunks = part.select_chunks(None)
    # price == pid in [1, 64], chunk_rows=16: predicate price >= 40
    # refutes the first two chunks outright
    sel = part.select_chunks(req["Part__F"].pred, {"__p0": 40.0})
    assert len(sel) < len(all_chunks)
    assert sel == [2, 3]
    # and the selection adapts with the parameter
    assert part.select_chunks(req["Part__F"].pred, {"__p0": 60.0}) == [3]
    assert part.select_chunks(req["Part__F"].pred, {"__p0": -1.0}) \
        == all_chunks


def test_pruned_scan_reads_fewer_columns_and_chunks(data, dataset):
    """Acceptance: the storage scan demonstrably reads fewer columns
    and fewer chunks than a full load (counters)."""
    dataset.load_env()
    full = dict(STORAGE_STATS)
    sp, cp = compile_family(40.0)
    req = storage_requirements(cp, set(dataset.parts))
    reset_storage_stats()
    env = dataset.load_env(
        columns={p: r.columns for p, r in req.items()},
        preds={p: r.pred for p, r in req.items()},
        params={"__p0": 40.0})
    pruned = dict(STORAGE_STATS)
    assert pruned["columns_read"] < full["columns_read"]
    assert pruned["chunks_read"] < full["chunks_read"]
    assert pruned["chunks_skipped"] > 0
    assert pruned["bytes_read"] < full["bytes_read"]
    # and the pruned load still computes the right answer
    out = CG.run_flat_program(cp, env)
    man = sp.manifests["Q"]
    parts = {(): out[man.top]}
    for path, name in man.dicts.items():
        parts[path] = out[name]
    env_mem = CG.columnar_shred_inputs(data, INPUT_TYPES)
    out_mem = CG.run_flat_program(cp, env_mem)
    parts_mem = {(): out_mem[man.top]}
    for path, name in man.dicts.items():
        parts_mem[path] = out_mem[name]
    assert norm(CG.parts_to_rows(parts, man.ty)) == \
        norm(CG.parts_to_rows(parts_mem, man.ty))


# ---------------------------------------------------------------------------
# query parity: run_flat_program over a lazy StorageEnv
# ---------------------------------------------------------------------------

def test_run_flat_program_parity_storage_env(data, dataset):
    """Acceptance: same unshredded result over the persisted dataset as
    over the in-memory shredded value (eager path, ScanP storage
    mode)."""
    sp, cp = compile_family(32.0)
    man = sp.manifests["Q"]
    cat = StorageCatalog(dataset.dir.rsplit("/", 1)[0])
    env_lazy = cat.env("shop", cp)
    out_disk = CG.run_flat_program(cp, env_lazy)
    assert STORAGE_STATS["columns_pruned"] > 0    # mfgr / note unread
    # each part loads exactly once, with only its pruned columns —
    # the plain-ScanP ensure must not force a full-column reload
    assert STORAGE_STATS["parts_loaded"] == 3
    assert STORAGE_STATS["columns_read"] == 8     # of 10 total
    env_mem = CG.columnar_shred_inputs(data, INPUT_TYPES)
    out_mem = CG.run_flat_program(cp, env_mem)

    def rows_of(out):
        parts = {(): out[man.top]}
        for path, name in man.dicts.items():
            parts[path] = out[name]
        return CG.parts_to_rows(parts, man.ty)

    assert norm(rows_of(out_disk)) == norm(rows_of(out_mem))


# ---------------------------------------------------------------------------
# query parity + warm behavior: QueryService.execute_stored
# ---------------------------------------------------------------------------

def test_query_service_stored_parity_and_warm_skipping(data, dataset):
    """Acceptance: QueryService parity with the in-memory path, plus
    warm calls with new N.Param values -> zero retraces while chunk
    selection changes."""
    svc = QueryService(INPUT_TYPES, catalog=CATALOG)
    env = svc.shred_inputs(data)

    out_mem = svc.execute(family(32.0), env)
    rows_mem = svc.unshred(family(32.0), env, out_mem, "Q")

    CG.reset_trace_stats()
    out_disk = svc.execute_stored(family(32.0), ds := dataset)
    rows_disk = svc.unshred_stored(family(32.0), ds, out_disk, "Q")
    assert norm(rows_mem) == norm(rows_disk)
    cold_traces = CG.TRACE_STATS.get("traces", 0)
    assert svc.stats["misses"] == 2          # one memory, one stored

    # warm: different constants = same family; chunk selection adapts
    reset_storage_stats()
    out2 = svc.execute_stored(family(60.0), ds)
    assert CG.TRACE_STATS.get("traces", 0) == cold_traces
    assert svc.stats["hits"] >= 1
    warm_hi = dict(STORAGE_STATS)
    reset_storage_stats()
    out3 = svc.execute_stored(family(-5.0), ds)
    assert CG.TRACE_STATS.get("traces", 0) == cold_traces
    warm_all = dict(STORAGE_STATS)
    assert warm_hi["chunks_skipped"] > warm_all["chunks_skipped"]
    assert warm_hi["chunks_read"] < warm_all["chunks_read"]

    # parity at both new parameter values
    rows2 = svc.unshred_stored(family(60.0), ds, out2, "Q")
    mem2 = svc.unshred(family(60.0), env, svc.execute(family(60.0), env),
                       "Q")
    assert norm(rows2) == norm(mem2)
    rows3 = svc.unshred_stored(family(-5.0), ds, out3, "Q")
    mem3 = svc.unshred(family(-5.0), env, svc.execute(family(-5.0), env),
                       "Q")
    assert norm(rows3) == norm(mem3)


def test_execute_routes_stored_dataset(data, dataset):
    """``QueryService.execute`` / ``unshred`` accept a StoredDataset
    directly in place of an in-memory environment."""
    svc = QueryService(INPUT_TYPES, catalog=CATALOG)
    env = svc.shred_inputs(data)
    out_disk = svc.execute(family(20.0), dataset)
    rows_disk = svc.unshred(family(20.0), dataset, out_disk, "Q")
    rows_mem = svc.unshred(family(20.0), env,
                           svc.execute(family(20.0), env), "Q")
    assert norm(rows_disk) == norm(rows_mem)


def test_stored_cache_misses_on_dataset_change(data, dataset, tmp_path):
    """Appending data changes the dataset fingerprint -> new entry."""
    svc = QueryService(INPUT_TYPES, catalog=CATALOG)
    svc.execute_stored(family(10.0), dataset)
    assert svc.stats["misses"] == 1
    cat = StorageCatalog(str(tmp_path))
    w = cat.writer("shop2", INPUT_TYPES, chunk_rows=16)
    w.append(data)
    ds2 = cat.open("shop2")
    svc.execute_stored(family(10.0), ds2)    # same rows, same key shape
    w.append({"Ord": data["Ord"][:3]})
    ds2b = cat.open("shop2", refresh=True)
    svc.execute_stored(family(10.0), ds2b)
    assert svc.stats["misses"] == 3          # grown dataset recompiles


# ---------------------------------------------------------------------------
# persisted physical props
# ---------------------------------------------------------------------------

def test_storage_env_widens_loaded_columns(data, dataset):
    """Two assignments reading DISJOINT column sets of one stored part:
    the second scan must widen the lazily loaded column set (regression:
    the ensure hook used to skip parts already present in the env)."""
    Part = N.Var("Part", PART_T)
    q1 = N.SumBy(N.for_in("p", Part, lambda p:
                          N.Singleton(N.record(pid=p.pid, v=p.price))),
                 keys=("pid",), values=("v",))
    q2 = N.SumBy(N.for_in("p", Part, lambda p:
                          N.Singleton(N.record(mfgr=p.mfgr, c=p.pname))),
                 keys=("mfgr",), values=("c",))
    prog = N.Program([N.Assignment("A", q1), N.Assignment("B", q2)])
    sp = M.shred_program(prog, INPUT_TYPES, domain_elimination=True)
    cp = CG.compile_program(sp, CATALOG)
    cat = StorageCatalog(dataset.dir.rsplit("/", 1)[0])
    out = CG.run_flat_program(cp, cat.env("shop", cp))
    mem = CG.run_flat_program(cp, CG.columnar_shred_inputs(data,
                                                           INPUT_TYPES))
    for name in ("A", "B"):
        for c in mem[name].data:
            assert np.array_equal(
                np.asarray(mem[name].data[c])[np.asarray(mem[name].valid)],
                np.asarray(out[name].data[c])[np.asarray(out[name].valid)])


def test_append_invalidates_persisted_props(data, tmp_path):
    """A second batch breaks global sortedness: the footer must drop
    sorted_by/partitioning captured from the first write_parts — and a
    second write_parts on the same part is refused outright (labels
    cannot be offset for a bundle)."""
    from repro.columnar.props import PhysicalProps
    env = CG.columnar_shred_inputs(data, INPUT_TYPES)
    bag = env["Part__F"].with_props(PhysicalProps(sorted_by=("pid",)))
    cat = StorageCatalog(str(tmp_path))
    w = cat.writer("grow", INPUT_TYPES, chunk_rows=16)
    w.write_parts({"Part__F": bag})
    assert w.meta.parts["Part__F"].sorted_by == ("pid",)
    with pytest.raises(AssertionError):
        w.write_parts({"Part__F": bag})
    w.append({"Part": data["Part"]})     # appended: order now broken
    assert w.meta.parts["Part__F"].sorted_by is None
    part = cat.open("grow", refresh=True).parts["Part__F"]
    assert part.load().props.sorted_by is None


def test_pruned_scan_keeps_rowid(data):
    """A pruned with_rowid scan still generates alias.__rowid
    (regression: _eval_pruned dropped the flag)."""
    from repro.core.plans import ScanP, _PrunedScan, eval_plan
    env = CG.columnar_shred_inputs(data, INPUT_TYPES)
    p = _PrunedScan(ScanP("Part__F", "x", with_rowid=True),
                    frozenset({"x.pid", "x.__rowid"}))
    bag = eval_plan(p, env)
    assert sorted(bag.columns) == ["x.__rowid", "x.pid"]


def test_zero_row_append_keeps_props(data, tmp_path):
    """An append contributing no rows must not invalidate persisted
    sort/partition props (the on-disk bytes are unchanged)."""
    from repro.columnar.props import PhysicalProps
    env = CG.columnar_shred_inputs(data, INPUT_TYPES)
    bag = env["Part__F"].with_props(PhysicalProps(sorted_by=("pid",)))
    cat = StorageCatalog(str(tmp_path))
    w = cat.writer("z", INPUT_TYPES, chunk_rows=16)
    w.write_parts({"Part__F": bag})
    w.append({"Part": []})
    assert w.meta.parts["Part__F"].sorted_by == ("pid",)


def test_resume_after_interrupted_append(data, tmp_path):
    """Regression: a crash mid-append leaves chunk files of the aborted
    batch on disk (the last one partial/corrupt) while the footer still
    describes the previous state. ``resume=True`` must take its row
    totals — and therefore the label bases of the next append — from
    the FOOTER, never from the stray files, and re-appending must
    overwrite the stale chunks: the final dataset is bit-for-bit the
    uninterrupted stream."""
    import os
    cat = StorageCatalog(str(tmp_path))
    orders = data["Ord"]
    w = cat.writer("intr", INPUT_TYPES, chunk_rows=16)
    w.append({"Ord": orders[:20], "Part": data["Part"]})
    # simulate the interrupted second append: for every column of the
    # Ord top part, the next chunk file landed (index == current chunk
    # count) but the footer was never rewritten; one file is truncated
    pm = w.meta.parts["Ord__F"]
    idx = len(pm.chunks)
    for col in pm.schema:
        path = os.path.join(w.dir, "Ord__F", col, f"c{idx:05d}.npy")
        os.makedirs(os.path.dirname(path), exist_ok=True)
        np.save(path, np.arange(13, dtype=np.int64))
    with open(path, "r+b") as f:
        f.truncate(40)                      # partial last chunk
    # restarted process: resume and replay the remaining rows
    w2 = cat.writer("intr", INPUT_TYPES, chunk_rows=16, resume=True)
    assert w2.meta.parts["Ord__F"].rows == 20   # footer, not files
    w2.append({"Ord": orders[20:]})
    env_mem = CG.columnar_shred_inputs(data, INPUT_TYPES)
    env_disk = cat.open("intr", refresh=True).load_env()
    for name, bag in env_mem.items():
        for c in bag.data:
            assert np.array_equal(np.asarray(bag.data[c]),
                                  np.asarray(env_disk[name].data[c])), \
                (name, c)


def test_sketch_persists_and_resumes(data, tmp_path):
    """The streaming heavy-key sketch rides the footer: totals count
    every appended batch exactly once, survive resume, and feed
    ``table_stats`` for the automatic skew pass."""
    from repro.storage import table_stats
    cat = StorageCatalog(str(tmp_path))
    orders = data["Ord"]
    w = cat.writer("sk", INPUT_TYPES, chunk_rows=16)
    w.append({"Ord": orders[:20], "Part": data["Part"]})
    w2 = cat.writer("sk", INPUT_TYPES, chunk_rows=16, resume=True)
    w2.append({"Ord": orders[20:]})
    ds = cat.open("sk", refresh=True)
    st = table_stats(ds)
    ts = st["Ord__D_oparts"]
    assert ts.rows == ds.parts["Ord__D_oparts"].rows
    from repro.core.skew import HeavyKeySketch
    sk = HeavyKeySketch.from_json(
        ds.parts["Ord__D_oparts"].meta.sketches["pid"])
    assert sk.total == ts.rows          # streamed once, no double count
    # note=7 on every row: the constant column is maximally heavy
    sk_note = HeavyKeySketch.from_json(
        ds.parts["Ord__D_oparts"].meta.sketches["note"])
    assert dict(sk_note.heavy(0.5)) == {7: ts.rows}
    # reals carry no sketch (not equi-join keys)
    assert "qty" not in ds.parts["Ord__D_oparts"].meta.sketches


def test_resume_rejects_conflicting_encoder(tmp_path):
    rows = [{"k": 1, "city": "lyon"}, {"k": 2, "city": "oslo"}]
    cat = StorageCatalog(str(tmp_path))
    cat.writer("c", {"R": STR_T}, chunk_rows=4).write({"R": rows})
    # a fresh empty encoder resumes fine and inherits the vocab
    enc = {}
    w = cat.writer("c", {"R": STR_T}, chunk_rows=4, encoders=enc,
                   resume=True)
    assert enc["city"].rev == ["lyon", "oslo"]
    w.append({"R": [{"k": 3, "city": "kobe"}]})
    assert cat.open("c", refresh=True).encoders["city"].rev == \
        ["lyon", "oslo", "kobe"]
    # a conflicting encoder (would remap on-disk codes) is refused
    bad = {"city": StringEncoder.from_vocab(["oslo"])}
    with pytest.raises(AssertionError):
        cat.writer("c", {"R": STR_T}, chunk_rows=4, encoders=bad,
                   resume=True)


def test_eager_params_drive_chunk_selection(data, dataset):
    """ExecSettings.params reach zone-map selection on the eager path:
    a binding LOOSER than the lifted default must not skip chunks the
    evaluator's predicate would keep."""
    from repro.core.plans import ExecSettings
    from repro.serve.query_service import lift_program
    lifted, _ = lift_program(family(60.0))   # default would skip a lot
    sp = M.shred_program(lifted, INPUT_TYPES, domain_elimination=True)
    cp = CG.compile_program(sp, CATALOG)
    man = sp.manifests["Q"]
    cat = StorageCatalog(dataset.dir.rsplit("/", 1)[0])

    def rows_with(params):
        env = cat.env("shop", cp)            # no params at env build
        out = CG.run_flat_program(cp, env, ExecSettings(params=params))
        parts = {(): out[man.top]}
        for path, name in man.dicts.items():
            parts[path] = out[name]
        return CG.parts_to_rows(parts, man.ty)

    env_mem = CG.columnar_shred_inputs(data, INPUT_TYPES)
    out_mem = CG.run_flat_program(cp, env_mem,
                                  ExecSettings(params={"__p0": 2.0}))
    parts_mem = {(): out_mem[man.top]}
    for path, name in man.dicts.items():
        parts_mem[path] = out_mem[name]
    assert norm(rows_with({"__p0": 2.0})) == \
        norm(CG.parts_to_rows(parts_mem, man.ty))


def test_zone_maps_exact_beyond_float53(tmp_path):
    """Integer zone bounds above 2**53 stay exact (a float bound would
    round and skip a matching chunk)."""
    big = 2 ** 53 + 1
    BIG_T = N.bag(N.tuple_t(k=N.INT))
    cat = StorageCatalog(str(tmp_path))
    cat.writer("big", {"R": BIG_T}, chunk_rows=4).write(
        {"R": [{"k": big}, {"k": big}]})
    part = cat.open("big").parts["R__F"]
    z = part.meta.chunks[0].zones["k"]
    assert z["lo"] == big and isinstance(z["lo"], int)
    pred = N.Cmp(">", N.Var("k", N.INT), N.Const(big - 1, N.INT))
    assert part.select_chunks(pred) == [0]


def test_props_persist_through_write_parts(data, tmp_path):
    from repro.columnar.props import PhysicalProps
    env = CG.columnar_shred_inputs(data, INPUT_TYPES)
    bag = env["Part__F"]     # generated sorted by pid already
    bag = bag.with_props(PhysicalProps(sorted_by=("pid",),
                                       partitioning=("pid",)))
    cat = StorageCatalog(str(tmp_path))
    w = cat.writer("props", INPUT_TYPES, chunk_rows=16)
    w.write_parts({"Part__F": bag})
    part = cat.open("props").parts["Part__F"]
    assert part.meta.sorted_by == ("pid",)
    assert part.meta.partitioning == ("pid",)
    loaded = part.load()
    assert loaded.props.sorted_by == ("pid",)
    assert loaded.props.partitioning == ("pid",)
    assert loaded.props.invalid_last
    # column-pruned load keeps the surviving prefix only
    pruned = part.load(columns=["pname"])
    assert pruned.props.sorted_by is None
    assert pruned.props.partitioning is None


# ---------------------------------------------------------------------------
# fault model: checksums, typed errors, torn-append recovery (PR 6)
# ---------------------------------------------------------------------------

def test_chunk_crc_detects_silent_corruption(data, tmp_path):
    """A bit flip that keeps the row count is invisible to the plain
    load but caught by ``verify=True`` via the footer CRC32."""
    import os
    from repro.errors import ChunkCorruptionError
    cat = StorageCatalog(str(tmp_path))
    ds = cat.write("crc", data, INPUT_TYPES, chunk_rows=16)
    part = ds.parts["Part__F"]
    assert all("pid" in c.crcs for c in part.meta.chunks)
    path = os.path.join(ds.dir, "Part__F", "pid", "c00000.npy")
    with open(path, "r+b") as f:        # flip one payload byte
        f.seek(-1, os.SEEK_END)
        b = f.read(1)
        f.seek(-1, os.SEEK_END)
        f.write(bytes([b[0] ^ 0xFF]))
    part.load()                         # row counts still agree
    with pytest.raises(ChunkCorruptionError):
        part.load(verify=True)


def test_footer_without_crcs_still_loads(data, tmp_path):
    """Backward compatibility: footers written before checksums exist
    load and even pass ``verify=True`` (nothing to check against)."""
    import json
    import os
    cat = StorageCatalog(str(tmp_path))
    ds = cat.write("old", data, INPUT_TYPES, chunk_rows=16)
    fpath = os.path.join(ds.dir, "footer.json")
    with open(fpath) as f:
        doc = json.load(f)
    for pm in doc["parts"].values():
        for c in pm["chunks"]:
            c.pop("crcs", None)
    with open(fpath, "w") as f:
        json.dump(doc, f)
    ds2 = cat.open("old", refresh=True)
    assert not ds2.parts["Part__F"].meta.chunks[0].crcs
    ds2.parts["Part__F"].load(verify=True)      # no CRCs -> no check


def test_footer_errors_are_typed(tmp_path):
    from repro.errors import FooterError
    from repro.storage import StoredDataset
    with pytest.raises(FooterError):
        StoredDataset(str(tmp_path / "no_such_dataset"))
    d = tmp_path / "broken"
    d.mkdir()
    (d / "footer.json").write_text("{not json")
    with pytest.raises(FooterError):
        StoredDataset(str(d))


def test_injected_chunk_faults_raise_typed_errors(data, tmp_path):
    from repro.errors import ChunkCorruptionError, MissingChunkError
    from repro.faults import FAULTS
    cat = StorageCatalog(str(tmp_path))
    ds = cat.write("fi", data, INPUT_TYPES, chunk_rows=16)
    part = ds.parts["Part__F"]
    try:
        FAULTS.reset(0)
        FAULTS.arm("storage.chunk", "missing", first=0, count=1)
        with pytest.raises(MissingChunkError):
            part.load()
        FAULTS.reset(0)
        FAULTS.arm("storage.chunk", "torn", first=0, count=1, arg=0.5)
        with pytest.raises(ChunkCorruptionError):
            part.load()                 # row-count check, no verify
        FAULTS.reset(0)
        FAULTS.arm("storage.chunk", "corrupt", first=0, count=1)
        part.load()                     # silent without verify
        FAULTS.reset(0)
        FAULTS.arm("storage.chunk", "corrupt", first=0, count=1)
        with pytest.raises(ChunkCorruptionError):
            part.load(verify=True)
    finally:
        FAULTS.reset()


def test_resume_quarantines_stale_sketch(data, tmp_path):
    """Regression (PR 6): a torn append can persist sketch counters
    counting rows whose chunks never made the footer. ``resume`` must
    quarantine any sketch whose stream total exceeds the part's footer
    rows — skew decisions must not read statistics the data does not
    back."""
    import json
    import os
    cat = StorageCatalog(str(tmp_path))
    orders = data["Ord"]
    w = cat.writer("stale", INPUT_TYPES, chunk_rows=16)
    w.append({"Ord": orders[:20], "Part": data["Part"]})
    rows0 = w.meta.parts["Ord__D_oparts"].rows
    # simulate the torn state: footer sketch total ahead of footer rows
    fpath = os.path.join(w.dir, "footer.json")
    with open(fpath) as f:
        doc = json.load(f)
    sk = doc["parts"]["Ord__D_oparts"]["sketches"]["pid"]
    sk["total"] = int(sk["total"]) + 50
    with open(fpath, "w") as f:
        json.dump(doc, f)
    w2 = cat.writer("stale", INPUT_TYPES, chunk_rows=16, resume=True)
    assert "pid" in w2.quarantined_sketches["Ord__D_oparts"]
    # untainted sketches survive the quarantine
    assert "note" not in w2.quarantined_sketches.get("Ord__D_oparts", {})
    w2.append({"Ord": orders[20:]})
    ds = cat.open("stale", refresh=True)
    pm = ds.parts["Ord__D_oparts"].meta
    from repro.core.skew import HeavyKeySketch
    # the rebuilt sketch counts ONLY rows appended after the quarantine
    assert HeavyKeySketch.from_json(pm.sketches["pid"]).total \
        == pm.rows - rows0
    assert HeavyKeySketch.from_json(pm.sketches["note"]).total == pm.rows


def test_append_rolls_back_in_memory_state_on_failure(data, tmp_path,
                                                      monkeypatch):
    """A failed append must not leave the writer's in-memory sketches /
    chunk lists ahead of the footer: a later successful flush would
    otherwise persist exactly the torn state ``resume`` quarantines."""
    from repro.core.skew import HeavyKeySketch
    cat = StorageCatalog(str(tmp_path))
    orders = data["Ord"]
    w = cat.writer("txn", INPUT_TYPES, chunk_rows=16)
    w.append({"Ord": orders[:20], "Part": data["Part"]})
    import repro.storage.writer as W
    real_save = np.save
    calls = {"n": 0}

    def flaky_save(path, arr):
        calls["n"] += 1
        if calls["n"] == 3:
            raise OSError("disk full (injected)")
        return real_save(path, arr)

    monkeypatch.setattr(W.np, "save", flaky_save)
    with pytest.raises(OSError):
        w.append({"Ord": orders[20:35]})
    monkeypatch.setattr(W.np, "save", real_save)
    w.append({"Ord": orders[20:]})
    ds = cat.open("txn", refresh=True)
    pm = ds.parts["Ord__D_oparts"].meta
    # sketch totals match footer rows exactly: no double count from the
    # aborted batch
    assert HeavyKeySketch.from_json(pm.sketches["pid"]).total == pm.rows
    env_mem = CG.columnar_shred_inputs(data, INPUT_TYPES)
    env_disk = ds.load_env()
    for name, bag in env_mem.items():
        for c in bag.data:
            assert np.array_equal(np.asarray(bag.data[c]),
                                  np.asarray(env_disk[name].data[c])), \
                (name, c)


# ---------------------------------------------------------------------------
# compressed chunks (PR 7): format compatibility, fault detection,
# stats split, morsel planning
# ---------------------------------------------------------------------------

def test_raw_footer_backward_compat(data, tmp_path):
    """``encoding="raw"`` writes the pre-compression format exactly —
    no ``encodings`` descriptors anywhere in the footer — and the
    current reader loads it bit-identically to an auto-encoded dataset
    of the same inputs, which must come out strictly smaller on disk."""
    import json
    import os
    from repro.storage.format import dir_bytes
    cat = StorageCatalog(str(tmp_path))
    raw = cat.write("raw", data, INPUT_TYPES, chunk_rows=16,
                    encoding="raw")
    enc = cat.write("enc", data, INPUT_TYPES, chunk_rows=16)
    with open(os.path.join(raw.dir, "footer.json")) as f:
        doc = json.load(f)
    for pm in doc["parts"].values():
        for c in pm["chunks"]:
            assert "encodings" not in c
    assert any(c.encodings for p in enc.parts.values()
               for c in p.meta.chunks)
    env_raw, env_enc = raw.load_env(), enc.load_env()
    assert set(env_raw) == set(env_enc)
    for name in env_raw:
        a, b = env_raw[name], env_enc[name]
        assert a.capacity == b.capacity
        for c in a.data:
            assert np.array_equal(np.asarray(a.data[c]),
                                  np.asarray(b.data[c])), (name, c)
        assert np.array_equal(np.asarray(a.valid),
                              np.asarray(b.valid)), name
    # the footprint win needs realistic chunks — at 16-row chunks the
    # npy headers and footer descriptors drown the codec savings
    raw2 = cat.write("raw2", data, INPUT_TYPES, encoding="raw")
    enc2 = cat.write("enc2", data, INPUT_TYPES)
    assert dir_bytes(enc2.dir) < dir_bytes(raw2.dir)


def test_corrupt_encoded_blob_detected(data, tmp_path):
    """A bit flip inside an encoded blob's values member keeps the
    decoded row count intact, so the plain load stays silent; the
    footer CRC — computed over the DECODED domain — catches it under
    ``verify=True``."""
    import os
    from repro.errors import ChunkCorruptionError
    from repro.storage.format import chunk_path
    cat = StorageCatalog(str(tmp_path))
    ds = cat.write("cenc", data, INPUT_TYPES, chunk_rows=16)
    part = ds.parts["Ord__D_oparts"]
    desc = part.meta.chunks[0].encodings["note"]
    assert desc["codec"] == "rle"       # constant column
    blob_size = max(off + count * np.dtype(dts).itemsize
                    for _, dts, count, off in desc["members"])
    val_off = next(off for name, _, _, off in desc["members"]
                   if name == "values")
    path = chunk_path(ds.dir, "Ord__D_oparts", "note", 0)
    payload_off = os.path.getsize(path) - blob_size + val_off
    with open(path, "r+b") as f:        # flip values[0]'s low byte
        f.seek(payload_off)
        b = f.read(1)
        f.seek(payload_off)
        f.write(bytes([b[0] ^ 0xFF]))
    part.load()                         # rows agree -> silent
    with pytest.raises(ChunkCorruptionError):
        part.load(verify=True)


def test_compressed_scan_reads_fewer_bytes_than_it_decodes(data,
                                                           tmp_path):
    """The stats split: ``bytes_read`` counts chunk files on disk,
    ``bytes_decoded`` the logical arrays they expand to. On an
    auto-encoded dataset the former must be strictly smaller."""
    cat = StorageCatalog(str(tmp_path))
    ds = cat.write("sts", data, INPUT_TYPES)    # one chunk per column
    reset_storage_stats()
    part = ds.parts["Ord__D_oparts"]
    part.load()
    logical = sum(np.dtype(part.meta.dtypes[c]).itemsize
                  for c in part.meta.dtypes) * part.meta.rows
    assert STORAGE_STATS["bytes_decoded"] == logical
    assert STORAGE_STATS["bytes_read"] < STORAGE_STATS["bytes_decoded"]
    assert STORAGE_STATS["chunks_decoded"] > 0


def test_plan_morsels_windows_partition_rows(data, tmp_path):
    from repro.storage import plan_morsels
    cat = StorageCatalog(str(tmp_path))
    ds = cat.write("mp", data, INPUT_TYPES, chunk_rows=8)
    mp = plan_morsels(ds, "Ord", 16)
    assert mp.n_morsels >= 3
    for name in mp.parts:
        wins = [m[name] for m in mp.morsels]
        rows = ds.parts[name].meta.rows
        # contiguous cover of [0, rows)
        assert wins[0].lo == 0 and wins[-1].hi == rows
        for a, b in zip(wins, wins[1:]):
            assert a.hi == b.lo
        # the pinned capacity class holds every window's chunk rows
        sizes = [c.rows for c in ds.parts[name].meta.chunks]
        assert mp.caps[name] >= max(
            (sum(sizes[i] for i in w.chunks) for w in wins), default=0)


def test_plan_morsels_rejects_unstreamable_labels(data, tmp_path):
    """``write_parts`` persists label values verbatim. Input-shaped
    bundles (labels = parent rids) stream; combine64-style or shuffled
    labels must be refused with the typed error rather than streamed
    into a wrong (partial) answer."""
    from repro.errors import StreamingUnsupportedError
    from repro.storage import plan_morsels
    cat = StorageCatalog(str(tmp_path))

    def write(name, mangle):
        env = CG.columnar_shred_inputs(data, INPUT_TYPES)
        child = env["Ord__D_oparts"]
        child.data["label"] = mangle(
            np.asarray(child.data["label"]).copy())
        w = cat.writer(name, INPUT_TYPES, chunk_rows=16)
        w.write_parts(env)
        return cat.open(name, refresh=True)

    # labels = parent rids: the bundle is input-shaped and streams
    ok = write("wp_ok", lambda lab: lab)
    assert plan_morsels(ok, "Ord", 16).n_morsels >= 3
    # combine64-style values never cover the parent rid range
    with pytest.raises(StreamingUnsupportedError):
        plan_morsels(write("wp_c64", lambda lab: lab << np.int64(32)),
                     "Ord", 16)
    # shuffled labels: chunk zone maps overlap / in-chunk order breaks
    with pytest.raises(StreamingUnsupportedError):
        plan_morsels(write("wp_shuf", lambda lab: lab[::-1].copy()),
                     "Ord", 16)
