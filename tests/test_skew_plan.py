"""Compiler-integrated automated skew handling: the streaming
heavy-key sketch, the plan-time decision (``apply_skew_program``), the
``SkewJoinP`` lowering, and the degenerate cases — zero heavy keys
(byte-identical plan + identical SHUFFLE_STATS vs the plain join), all
keys heavy, and a heavy key absent from the probe side.

Distributed assertions run on a single-device mesh: collective COUNTS
and trace counts are trace-time host counters, so the plan shape is
fully observable without the 8-virtual-device subprocess (which the
differential suite covers)."""

import numpy as np
import pytest

from repro.core import codegen as CG
from repro.core import interpreter as I
from repro.core import materialization as M
from repro.core import nrc as N
from repro.core import skew as SK
from repro.core.plans import SkewJoinP, _walk_plan, collect_plan_params
from repro.core.unnesting import Catalog
from repro.exec.dist import device_mesh_1d

import helpers as H

CATALOG = Catalog(unique_keys={"Part__F": ("pid",)})
OPARTS = "COP__D_corders_oparts"


@pytest.fixture(scope="module")
def case():
    data = {"COP": H.gen_cop(n_cust=16, seed=2, zipf=0.6),
            "Part": H.gen_parts(29)}
    prog = N.Program([N.Assignment("Q", H.running_example_query())])
    sp = M.shred_program(prog, H.INPUT_TYPES, domain_elimination=True)
    direct = I.eval_expr(H.running_example_query(), data)
    return data, prog, sp, direct


def compile_with(sp, stats, **kw):
    kw.setdefault("skew_partitions", 8)
    return CG.compile_program(sp, CATALOG, skew_stats=stats, **kw)


def skew_nodes(cp):
    return [s for _, p in cp.plans for s in _walk_plan(p)
            if isinstance(s, SkewJoinP)]


def heavy_stats(keys, rows=500):
    return {OPARTS: SK.TableStats(
        rows=rows, distinct={"pid": 29},
        heavy={"pid": [(int(k), rows) for k in keys]})}


def run_dist(cp, sp, data, heavy_rebind=None):
    """One-device distributed run; returns (rows, metrics, runner)."""
    env = CG.columnar_shred_inputs(data, H.INPUT_TYPES)
    mesh = device_mesh_1d(1)
    runner, out, metrics = CG.compile_program_distributed(
        cp, env, mesh, cap_factor=16.0)
    if heavy_rebind is not None:
        out, metrics = runner(env, params=heavy_rebind)
    man = sp.manifests["Q"]
    parts = {(): out[man.top], **{p: out[n]
                                  for p, n in man.dicts.items()}}
    rows = CG.parts_to_rows(parts, H.running_example_query().ty)
    return rows, metrics, runner


# ---------------------------------------------------------------------------
# the streaming sketch
# ---------------------------------------------------------------------------

def test_sketch_streams_and_bounds():
    rng = np.random.RandomState(0)
    sk = SK.HeavyKeySketch(k=8)
    stream = np.concatenate([np.full(600, 7), rng.randint(0, 1000, 400)])
    rng.shuffle(stream)
    for i in range(0, 1000, 64):          # streamed in chunks
        sk.update(stream[i:i + 64])
    assert sk.total == 1000
    # guaranteed retention: frequency 600 > total/k = 125
    heavy = dict(sk.heavy(threshold=0.025))
    assert 7 in heavy
    # counts are lower bounds
    assert heavy[7] <= 600
    assert heavy[7] >= 600 - sk.error_bound()
    # JSON round trip is exact
    back = SK.HeavyKeySketch.from_json(sk.to_json())
    assert back.counts == sk.counts and back.total == sk.total


def test_sketch_uniform_has_no_heavy():
    rng = np.random.RandomState(1)
    sk = SK.HeavyKeySketch(k=16)
    sk.update(rng.randint(0, 10000, 5000))
    assert sk.heavy(threshold=0.025) == []


def test_decide_heavy_keys_threshold_and_partitions():
    ts = SK.TableStats(rows=1000, distinct={"pid": 50},
                       heavy={"pid": [(7, 300), (3, 10)]})
    # only the 30% key clears the 2.5% bar
    assert SK.decide_heavy_keys(ts, "pid", n_partitions=8) == [7]
    # one partition can never be imbalanced
    assert SK.decide_heavy_keys(ts, "pid", n_partitions=1) == []
    # unknown column: nothing
    assert SK.decide_heavy_keys(ts, "qty", n_partitions=8) == []


def test_pad_heavy_shape_and_order():
    a = SK.pad_heavy([9, 3, 7])
    assert a.shape == (SK.MAX_HEAVY,) and a.dtype == np.int64
    assert list(a[:3]) == [3, 7, 9]
    assert a[3] == np.iinfo(np.int64).max


# ---------------------------------------------------------------------------
# degenerate cases
# ---------------------------------------------------------------------------

def test_zero_heavy_keys_is_noop_vs_plain_join(case):
    """No predicted heavy keys -> no SkewJoinP, and the distributed
    execution is THE SAME PLAN as the skew-less compile: identical
    SHUFFLE_STATS (collectives, exchanges, elisions), no planned skew
    join, bit-identical results."""
    data, prog, sp, direct = case
    plain = CG.compile_program(sp, CATALOG)
    noop = compile_with(sp, heavy_stats([]))     # stats, zero heavy
    assert skew_nodes(noop) == [] and noop.skew_params == {}
    r_plain, m_plain, run_plain = run_dist(plain, sp, data)
    r_noop, m_noop, run_noop = run_dist(noop, sp, data)
    assert I.bags_equal(direct, r_plain) and I.bags_equal(direct, r_noop)
    for k in ("shuffle_collectives", "exchanges", "exchanges_elided",
              "shuffle_rows"):
        assert m_plain[k] == m_noop[k], (k, m_plain[k], m_noop[k])
    assert "skew_join_planned" not in run_noop.stats
    # sanity: an actually-heavy stat DOES change the plan
    auto = compile_with(sp, heavy_stats([7]))
    assert len(skew_nodes(auto)) == 1


def test_all_keys_heavy_parity(case):
    """Every probe key heavy: the whole probe side takes the broadcast
    path, the light exchange ships nothing — results unchanged."""
    data, prog, sp, direct = case
    cp = compile_with(sp, heavy_stats(list(range(1, 30))))
    (sj,) = skew_nodes(cp)
    assert len([k for k in sj.heavy_default
                if k != np.iinfo(np.int64).max]) == 29
    rows, metrics, runner = run_dist(cp, sp, data)
    assert I.bags_equal(direct, rows)
    assert runner.stats.get("skew_join_planned") == 1


def test_heavy_key_absent_from_probe_parity(case):
    """A heavy key that never occurs on the probe side: the split is
    empty on the heavy branch; parity must hold and a rebind to the
    absent key must equal the plain answer."""
    data, prog, sp, direct = case
    cp = compile_with(sp, heavy_stats([424242]))   # no such pid
    assert len(skew_nodes(cp)) == 1
    rows, metrics, _ = run_dist(cp, sp, data)
    assert I.bags_equal(direct, rows)


def test_warm_rebind_new_heavy_set_zero_retraces(case):
    """The plan-cache contract: a warm runner rebinds a DIFFERENT
    heavy-key set (runtime parameter) with zero retraces and correct
    results."""
    data, prog, sp, direct = case
    cp = compile_with(sp, heavy_stats([7]))
    (name,) = collect_plan_params(cp.graph)
    env = CG.columnar_shred_inputs(data, H.INPUT_TYPES)
    mesh = device_mesh_1d(1)
    CG.reset_trace_stats()
    runner, out, _ = CG.compile_program_distributed(cp, env, mesh,
                                                    cap_factor=16.0)
    t0 = CG.TRACE_STATS.get("traces", 0)
    for keys in ([3, 9, 21], [], list(range(1, 30))):
        out2, _m = runner(env, params={name: SK.pad_heavy(keys)})
        man = sp.manifests["Q"]
        parts = {(): out2[man.top],
                 **{p: out2[n] for p, n in man.dicts.items()}}
        rows = CG.parts_to_rows(parts, H.running_example_query().ty)
        assert I.bags_equal(direct, rows), keys
    assert CG.TRACE_STATS.get("traces", 0) == t0   # zero retraces


def test_local_jit_ignores_heavy_but_binds_param(case):
    """Locally a SkewJoinP degrades to its plain join; the heavy param
    still exists in the executable's binding surface (shape-stable
    family contract)."""
    data, prog, sp, direct = case
    cp = compile_with(sp, heavy_stats([7]))
    exe = CG.jit_program(cp)
    assert "__hk0" in exe.param_defaults
    env = CG.columnar_shred_inputs(data, H.INPUT_TYPES)
    out = exe(env, {"__hk0": SK.pad_heavy([5])})
    man = sp.manifests["Q"]
    parts = {(): out[man.top], **{p: out[n]
                                  for p, n in man.dicts.items()}}
    assert I.bags_equal(direct, CG.parts_to_rows(
        parts, H.running_example_query().ty))


def test_service_shrinking_rebind_fails_loudly(case):
    """A warm heavy-key rebind that SHRINKS the set can overflow the
    adaptively sized exchange buckets; the QueryService must raise
    (advising a re-warm) instead of returning silently truncated
    aggregates. A growing rebind keeps serving fine."""
    from repro.serve import QueryService
    data, prog, sp, direct = case
    mesh = device_mesh_1d(1)
    # tight buckets + adaptive: the warmup pins every site to its
    # exact need under the warm heavy-key set
    svc = QueryService(H.INPUT_TYPES, catalog=CATALOG, mesh=mesh,
                       dist_kwargs=dict(cap_factor=0.25, adaptive=True),
                       skew_partitions=8)
    env = CG.columnar_shred_inputs(data, H.INPUT_TYPES)
    hints = {OPARTS: {"pid": [7]}}       # zipf hot key broadcast-side
    svc.execute(prog, env, skew_hints=hints)
    # superset rebind: only moves rows to the broadcast path
    svc.execute(prog, env, skew_hints={OPARTS: {"pid": [7, 11]}})
    # shrinking rebind: the hot key floods the light bucket sized
    # without it -> loud typed failure (the serving runtime's cue to
    # evict + re-warm), not silent truncation
    from repro.errors import CapacityOverflowError
    with pytest.raises(CapacityOverflowError, match="re-warm"):
        svc.execute(prog, env, skew_hints={OPARTS: {"pid": [424242]}})


def test_service_hints_beyond_max_heavy_truncate(case):
    """More hinted keys than the static MAX_HEAVY bound truncate
    consistently with the compile-time decision instead of crashing."""
    from repro.serve import QueryService
    data, prog, sp, direct = case
    mesh = device_mesh_1d(1)
    svc = QueryService(H.INPUT_TYPES, catalog=CATALOG, mesh=mesh,
                       dist_kwargs=dict(cap_factor=16.0),
                       skew_partitions=8)
    env = CG.columnar_shred_inputs(data, H.INPUT_TYPES)
    many = list(range(1, SK.MAX_HEAVY + 12))
    out = svc.execute(prog, env, skew_hints={OPARTS: {"pid": many}})
    man = sp.manifests["Q"]
    parts = {(): out[man.top], **{p: out[n]
                                  for p, n in man.dicts.items()}}
    assert I.bags_equal(direct, CG.parts_to_rows(
        parts, H.running_example_query().ty))


def test_fused_join_agg_unfuses_under_skew():
    """A Gamma+ fused onto a qualifying join (FusedJoinAggP, the
    push_order physical fusion) un-fuses into Gamma+ over SkewJoinP
    when the probe statistics are skewed (placement beats fusion), and
    the rewritten plan evaluates to the same result locally."""
    from repro.columnar.table import FlatBag
    from repro.core import plans as P
    rng = np.random.RandomState(0)
    n = 64
    lrows = [{"k": 7 if rng.rand() < 0.5 else int(rng.randint(0, 8)),
              "g": int(rng.randint(0, 3)), "v": float(rng.randint(1, 5))}
             for _ in range(n)]
    left = FlatBag.from_rows(lrows, {"k": "int", "g": "int", "v": "real"},
                             capacity=n)
    right = FlatBag.from_rows([{"k": i, "w": float(10 * i)}
                               for i in range(8)],
                              {"k": "int", "w": "real"}, capacity=8)
    join = P.JoinP(P.ScanP("L", "l"), P.ScanP("R", "r"),
                   ("l.k",), ("r.k",))
    fused = P.push_order(P.SumAggP(join, keys=("l.g",), vals=("l.v",)))
    assert isinstance(fused, P.FusedJoinAggP)
    graph = P.build_program_graph([("Q", fused)], outputs=("Q",))
    stats = {"L": SK.TableStats(rows=n, distinct={"k": 8},
                                heavy={"k": [(7, n // 2)]})}
    info = P.apply_skew_program(graph, stats, n_partitions=8)
    (nd,) = graph.nodes
    assert isinstance(nd.plan, P.SumAggP)       # un-fused
    assert isinstance(nd.plan.child, P.SkewJoinP)
    assert info["__hk0"][0] == "L" and info["__hk0"][1] == "k"
    env = {"L": left, "R": right}
    got = P.eval_plan(nd.plan, env)
    want = {}
    for r in lrows:
        want[r["g"]] = want.get(r["g"], 0.0) + r["v"]
    host = {int(g): float(v) for g, v, ok in
            zip(np.asarray(got.col("l.g")), np.asarray(got.col("l.v")),
                np.asarray(got.valid)) if ok}
    assert host == want
