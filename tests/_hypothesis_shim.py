"""Minimal stand-in for the ``hypothesis`` API surface this test suite
uses, for environments where the real package is not installed (the
TPU container bakes only the jax toolchain; tier-1 must not depend on
pip). conftest.py registers this module as ``hypothesis`` ONLY when the
real library is missing — install hypothesis and it wins.

Semantics: deterministic pseudo-random example generation. ``@given``
draws ``max_examples`` examples from a seeded numpy RandomState (seed
derived from the test name, stable across runs) and calls the test once
per example. No shrinking, no database — a failing example prints its
drawn arguments instead.
"""

from __future__ import annotations

import functools
import types
import zlib

import numpy as np


class SearchStrategy:
    def example_from(self, rng: np.random.RandomState):
        raise NotImplementedError

    def map(self, fn):
        return _Mapped(self, fn)


class _Mapped(SearchStrategy):
    def __init__(self, inner, fn):
        self.inner, self.fn = inner, fn

    def example_from(self, rng):
        return self.fn(self.inner.example_from(rng))


class _Integers(SearchStrategy):
    def __init__(self, lo, hi):
        self.lo, self.hi = lo, hi

    def example_from(self, rng):
        return int(rng.randint(self.lo, self.hi + 1))


class _Booleans(SearchStrategy):
    def example_from(self, rng):
        return bool(rng.randint(0, 2))


class _Floats(SearchStrategy):
    def __init__(self, lo, hi):
        self.lo, self.hi = lo, hi

    def example_from(self, rng):
        return float(self.lo + (self.hi - self.lo) * rng.rand())


class _SampledFrom(SearchStrategy):
    def __init__(self, seq):
        self.seq = list(seq)

    def example_from(self, rng):
        return self.seq[int(rng.randint(0, len(self.seq)))]


class _Lists(SearchStrategy):
    def __init__(self, elem, min_size=0, max_size=10):
        self.elem, self.min_size, self.max_size = elem, min_size, max_size

    def example_from(self, rng):
        n = int(rng.randint(self.min_size, self.max_size + 1))
        return [self.elem.example_from(rng) for _ in range(n)]


class _Composite(SearchStrategy):
    def __init__(self, fn, args, kwargs):
        self.fn, self.args, self.kwargs = fn, args, kwargs

    def example_from(self, rng):
        def draw(strategy):
            return strategy.example_from(rng)

        return self.fn(draw, *self.args, **self.kwargs)


def composite(fn):
    @functools.wraps(fn)
    def make(*args, **kwargs):
        return _Composite(fn, args, kwargs)

    return make


strategies = types.ModuleType("hypothesis.strategies")
strategies.integers = lambda min_value, max_value: _Integers(min_value,
                                                             max_value)
strategies.booleans = _Booleans
strategies.floats = lambda min_value, max_value: _Floats(min_value, max_value)
strategies.sampled_from = _SampledFrom
strategies.lists = lambda elem, min_size=0, max_size=10: _Lists(
    elem, min_size, max_size)
strategies.composite = composite
strategies.SearchStrategy = SearchStrategy


_DEFAULT_MAX_EXAMPLES = 20


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None,
             **_ignored):
    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn

    return deco


def given(*strats, **kw_strats):
    def deco(fn):
        # NOTE: the wrapper must present a ZERO-argument signature —
        # pytest would otherwise read the wrapped test's parameters as
        # fixture requests (real hypothesis does the same erasure).
        def run():
            n = getattr(run, "_shim_max_examples",
                        getattr(fn, "_shim_max_examples",
                                _DEFAULT_MAX_EXAMPLES))
            base_seed = zlib.crc32(fn.__qualname__.encode())
            for i in range(n):
                rng = np.random.RandomState((base_seed + i) % (2 ** 31))
                drawn = [s.example_from(rng) for s in strats]
                kdrawn = {k: s.example_from(rng)
                          for k, s in kw_strats.items()}
                try:
                    fn(*drawn, **kdrawn)
                except Exception:
                    print(f"[hypothesis-shim] falsifying example "
                          f"#{i}: args={drawn} kwargs={kdrawn}")
                    raise

        run.__name__ = fn.__name__
        run.__qualname__ = fn.__qualname__
        run.__module__ = fn.__module__
        run.__doc__ = fn.__doc__
        return run

    return deco


HealthCheck = types.SimpleNamespace(all=lambda: [])
