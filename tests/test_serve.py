"""Serving engine smoke: batched prefill+decode produce tokens and the
KV-cache incremental path stays consistent with teacher forcing."""

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_smoke
from repro.models import transformer as T
from repro.serve import Request, ServeEngine


def test_serve_batched_generate():
    cfg = get_smoke("gemma_7b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_len=32, jit=False)
    reqs = [Request(prompt=[1, 2, 3], max_new_tokens=5),
            Request(prompt=[4, 5, 6], max_new_tokens=3)]
    outs = eng.generate(reqs)
    assert len(outs) == 2
    assert len(outs[0]) == 5 and len(outs[1]) == 3
    assert all(0 <= t < cfg.vocab for o in outs for t in o)


def test_serve_greedy_matches_forward():
    """First generated token == argmax of the teacher-forced logits."""
    cfg = get_smoke("deepseek_67b")
    params = T.init_params(cfg, jax.random.PRNGKey(1))
    prompt = [3, 1, 4, 1, 5]
    eng = ServeEngine(cfg, params, max_len=16, jit=False)
    out = eng.generate([Request(prompt=prompt, max_new_tokens=1)])[0]
    h = T.forward(cfg, params, jnp.asarray([prompt], jnp.int32))
    head = params["embed"] if cfg.tie_embeddings else params["head"]
    logits = h[:, -1].astype(jnp.float32) @ head.T.astype(jnp.float32)
    assert out[0] == int(jnp.argmax(logits, -1)[0])
