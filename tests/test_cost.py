"""Unit tests for the cost-based planner (``repro.core.cost``) and the
planner-stats bugfix sweep that rode along with it:

* cardinality estimator formulas (scan / select / fk join / heavy-key
  correction / aggregation) and the observed-rows override,
* golden decision flips: a stats change (small vs large build side)
  flips the costed join order; a skew change flips fuse-vs-unfuse,
* ``decide_heavy_keys`` driven by measured ``meters["rows"]`` in BOTH
  directions (the dead ``hasattr(effective_rows)`` guard is gone),
* ``HeavyKeySketch.update`` batched shed keeps exactly ``k`` survivors
  under adversarial tied batches (the old cut dropped every tie),
* ``cascade_send_rows_est`` degenerates to ``cascade_send_rows`` when
  every intermediate equals the spine.
"""

import numpy as np
import pytest

from repro.core import cost as C
from repro.core import plans as P
from repro.core import skew as SK


class _Node:
    def __init__(self, name, plan):
        self.name, self.plan = name, plan


class _Graph:
    def __init__(self, nodes):
        self.nodes = nodes


def _stats(part_rows=30, part_distinct=30, heavy=None):
    """A 3-relation chain: Lineitem (skewable pid) x Part x Orders."""
    return {
        "L": SK.TableStats(rows=1000,
                           distinct={"pid": 100, "oid": 500},
                           heavy={"pid": list(heavy or [])}),
        "Pt": SK.TableStats(rows=part_rows,
                            distinct={"pid": part_distinct}),
        "O": SK.TableStats(rows=500, distinct={"oid": 500}),
    }


def _chain(order=("O", "Pt")):
    """JoinP chain over L in the given build order; returns (root,
    graph)."""
    plan = P.ScanP("L", "l")
    on = {"O": ("l.oid", "o.oid", "o"), "Pt": ("l.pid", "p.pid", "p")}
    for bag in order:
        lcol, rcol, alias = on[bag]
        plan = P.JoinP(plan, P.ScanP(bag, alias), (lcol,), (rcol,))
    return plan, _Graph([_Node("T", plan)])


# ---------------------------------------------------------------------------
# estimator formulas
# ---------------------------------------------------------------------------

def test_scan_estimate_uses_effective_rows_distinct_heavy():
    est = C.CardinalityEstimator(_stats(heavy=[(7, 300)]), 8)
    e = est.estimate(P.ScanP("L", "l"))
    assert e.known and e.rows == 1000.0
    assert e.distinct["l.pid"] == 100.0
    assert e.heavy["l.pid"] == {7: 300.0}
    # measured rows (feedback) win over the stored estimate, and the
    # sketched per-key counts rescale with them
    st = _stats(heavy=[(7, 300)])
    st["L"].meters["rows"] = 500
    e2 = C.CardinalityEstimator(st, 8).estimate(P.ScanP("L", "l"))
    assert e2.rows == 500.0
    assert e2.heavy["l.pid"] == {7: 150.0}


def test_select_selectivity_equality_vs_inequality():
    from repro.core import nrc as N
    est = C.CardinalityEstimator(_stats(), 8)
    scan = P.ScanP("L", "l")
    var = N.Var("l.pid", N.INT)
    eq = P.SelectP(scan, N.Cmp("==", var, N.Const(7, N.INT)))
    lt = P.SelectP(scan, N.Cmp("<", var, N.Const(7, N.INT)))
    assert est.estimate(eq).rows == pytest.approx(10.0)   # 1000 / d=100
    assert est.estimate(lt).rows == pytest.approx(1000 / 3)


def test_fk_join_passthrough_and_selective_build():
    est = C.CardinalityEstimator(_stats(part_rows=100,
                                        part_distinct=100), 8)
    full = P.JoinP(P.ScanP("L", "l"), P.ScanP("Pt", "p"),
                   ("l.pid",), ("p.pid",))
    # build covers the whole key domain: the probe passes through
    assert est.estimate(full).rows == pytest.approx(1000.0)
    # build covers 30 of 100 keys: ~30% of probes survive
    est2 = C.CardinalityEstimator(_stats(part_rows=30,
                                         part_distinct=30), 8)
    sel = est2.estimate(full)
    assert 250 < sel.rows < 350


def test_heavy_key_correction_beats_uniform_formula():
    # 300 of 1000 rows share pid=7; a build side carrying pid=7 with
    # one row matches all 300 — the uniform formula would say ~10
    st = _stats(part_rows=1, part_distinct=1, heavy=[(7, 300)])
    st["Pt"].heavy = {"pid": [(7, 1)]}
    est = C.CardinalityEstimator(st, 8)
    j = P.JoinP(P.ScanP("L", "l"), P.ScanP("Pt", "p"),
                ("l.pid",), ("p.pid",), unique_right=False)
    assert est.estimate(j).rows == pytest.approx(300.0, rel=0.1)


def test_aggregation_groups_capped_by_distinct():
    est = C.CardinalityEstimator(_stats(), 8)
    agg = P.SumAggP(P.ScanP("L", "l"), keys=("l.pid",), vals=("l.oid",))
    assert est.estimate(agg).rows == pytest.approx(100.0)
    dd = P.DeDupP(P.ScanP("L", "l"), cols=("l.oid",))
    assert est.estimate(dd).rows == pytest.approx(500.0)


def test_observed_rows_override_by_signature_digest():
    scan = P.ScanP("L", "l")
    dig = C.sig_digest(scan)
    est = C.CardinalityEstimator(_stats(), 8, observed={dig: 42})
    assert est.estimate(scan).rows == 42.0
    # digest is deterministic and structural: a fresh identical node
    # hits the same observation
    assert C.sig_digest(P.ScanP("L", "l")) == dig


# ---------------------------------------------------------------------------
# decision (a): golden join-order flips
# ---------------------------------------------------------------------------

def test_join_order_flips_with_build_selectivity():
    # selective Part (30/100 keys): joining it FIRST shrinks the
    # intermediate the Orders exchange re-ships -> reorder
    root, g = _chain(("O", "Pt"))
    est = C.CardinalityEstimator(_stats(part_rows=30, part_distinct=30),
                                 8)
    assert C.order_join_chains(g, est) == 1
    out = g.nodes[0].plan
    assert out.right.bag == "O" and out.left.right.bag == "Pt"

    # non-selective Part (covers every key): both orders ship the same
    # intermediates -> the tie keeps the program-written order
    root, g2 = _chain(("O", "Pt"))
    est2 = C.CardinalityEstimator(_stats(part_rows=100,
                                         part_distinct=100), 8)
    assert C.order_join_chains(g2, est2) == 0
    out2 = g2.nodes[0].plan
    assert out2.right.bag == "Pt" and out2.left.right.bag == "O"


def test_join_order_respects_key_dependencies():
    # stage 2's key lives on stage 1's build side: 2 can never move
    # before 1, whatever the cardinalities say
    l = P.ScanP("L", "l")
    j1 = P.JoinP(l, P.ScanP("Pt", "p"), ("l.pid",), ("p.pid",))
    j2 = P.JoinP(j1, P.ScanP("O", "o"), ("p.pid",), ("o.oid",))
    g = _Graph([_Node("T", j2)])
    st = _stats(part_rows=100, part_distinct=100)
    st["O"] = SK.TableStats(rows=2, distinct={"oid": 2})
    assert C.order_join_chains(g, C.CardinalityEstimator(st, 8)) == 0


def test_join_order_skipped_without_stats():
    root, g = _chain(("O", "Pt"))
    assert C.order_join_chains(g, C.CardinalityEstimator({}, 8)) == 0


# ---------------------------------------------------------------------------
# decision (c): fuse-vs-unfuse flips with skew intensity
# ---------------------------------------------------------------------------

def test_choose_unfuse_flips_with_skew():
    # Zipf-grade key (30% of rows): priced imbalance dwarfs the
    # light-exchange + replication + extra-pass cost -> un-fuse
    assert C.choose_unfuse(1000, [300], 8)
    # barely-heavy key (just over fair share): keep the fusion
    assert not C.choose_unfuse(1000, [130], 8)
    assert not C.choose_unfuse(1000, [], 8)       # no heavy keys
    assert not C.choose_unfuse(1000, [300], 1)    # one partition


def _fused_graph(heavy):
    j = P.JoinP(P.ScanP("L", "l"), P.ScanP("Pt", "p"),
                ("l.pid",), ("p.pid",))
    f = P.FusedJoinAggP(j, keys=("l.oid",), vals=("l.qty",))
    return _Graph([_Node("T", f)]), _stats(part_rows=100,
                                           part_distinct=100,
                                           heavy=heavy)


def test_costed_skew_pass_keeps_mild_fusion_without_param():
    g, st = _fused_graph(heavy=[(7, 130)])
    est = C.CardinalityEstimator(st, 8)
    defaults = P.apply_skew_program(g, st, 8, estimator=est)
    # kept fused — and crucially no dangling __hk parameter was
    # registered for the join that stayed fused
    assert isinstance(g.nodes[0].plan, P.FusedJoinAggP)
    assert defaults == {}


def test_costed_skew_pass_unfuses_heavy_skew():
    g, st = _fused_graph(heavy=[(7, 300)])
    est = C.CardinalityEstimator(st, 8)
    defaults = P.apply_skew_program(g, st, 8, estimator=est)
    out = g.nodes[0].plan
    assert isinstance(out, P.SumAggP)
    assert isinstance(out.child, P.SkewJoinP)
    assert set(defaults) == {"__hk0"}


def test_rule_based_skew_pass_still_always_unfuses():
    # estimator=None: PR 5's rule is byte-identical (cost_mode="off")
    g, st = _fused_graph(heavy=[(7, 130)])
    defaults = P.apply_skew_program(g, st, 8)
    assert isinstance(g.nodes[0].plan, P.SumAggP)
    assert set(defaults) == {"__hk0"}


# ---------------------------------------------------------------------------
# decision (b): estimated-intermediate cascade costing
# ---------------------------------------------------------------------------

def test_cascade_send_rows_est_degenerates_to_spine_assumption():
    rows = [1000, 100, 10]
    # intermediate ~ spine for every stage reproduces the old formula
    assert SK.cascade_send_rows_est(rows, [1000.0, 1000.0]) \
        == SK.cascade_send_rows(rows)
    # shrinking intermediates make the cascade cheaper ...
    assert SK.cascade_send_rows_est(rows, [50.0, 5.0]) \
        < SK.cascade_send_rows(rows)
    # ... expanding ones dearer
    assert SK.cascade_send_rows_est(rows, [5000.0, 9000.0]) \
        > SK.cascade_send_rows(rows)
    assert SK.cascade_send_rows_est([7], []) == 7


def test_chain_intermediates_feed_the_gate():
    est = C.CardinalityEstimator(_stats(part_rows=30, part_distinct=30),
                                 8)
    base = P.ScanP("L", "l")
    j1 = P.JoinP(base, P.ScanP("Pt", "p"), ("l.pid",), ("p.pid",))
    j2 = P.JoinP(j1, P.ScanP("O", "o"), ("l.oid",), ("o.oid",))
    inters = est.chain_intermediates(base, [j1, j2])
    assert inters is not None and len(inters) == 2
    assert inters[0] < 1000.0          # the selective build shrinks
    # missing stats -> None (caller falls back to the stats-free gate)
    assert C.CardinalityEstimator({}, 8).chain_intermediates(
        base, [j1, j2]) is None


# ---------------------------------------------------------------------------
# satellite: decide_heavy_keys flips on measured rows, both directions
# ---------------------------------------------------------------------------

def test_decide_heavy_keys_meters_flip_off_to_on():
    ts = SK.TableStats(rows=1000, heavy={"pid": [(7, 30)]})
    assert SK.decide_heavy_keys(ts, "pid", 8) == []     # 30 < 125
    ts.meters["rows"] = 100                             # need -> 13
    assert SK.decide_heavy_keys(ts, "pid", 8) == [7]


def test_decide_heavy_keys_meters_flip_on_to_off():
    ts = SK.TableStats(rows=100, heavy={"pid": [(7, 30)]})
    assert SK.decide_heavy_keys(ts, "pid", 8) == [7]    # 30 >= 13
    ts.meters["rows"] = 1000                            # need -> 125
    assert SK.decide_heavy_keys(ts, "pid", 8) == []


# ---------------------------------------------------------------------------
# satellite: batched Misra-Gries shed keeps exactly k under ties
# ---------------------------------------------------------------------------

def test_sketch_shed_keeps_exactly_k_on_tied_batch():
    sk = SK.HeavyKeySketch(k=4)
    sk.update(np.array([9, 9, 9]))          # borderline-heavy early key
    sk.update(np.array([1, 2, 3, 4, 5, 6]))  # adversarial: all tied at 1
    # exactly k survivors (the old code dropped every counter tied at
    # the cut, leaving only {9})
    assert len(sk.counts) == sk.k
    # the early key kept its lead over the fresh near-uniform batch
    assert sk.counts[9] == 2
    # deterministic (count, key) tiebreak: smallest keys survive
    assert set(sk.counts) == {9, 1, 2, 3}
    assert sk.error_bound() == 1


def test_sketch_shed_repeated_ties_stay_bounded_and_lower_bound():
    rng = np.random.default_rng(0)
    sk = SK.HeavyKeySketch(k=8)
    true = {}
    for i in range(30):
        batch = np.concatenate([
            np.full(20, 77),                       # the real heavy key
            rng.integers(1000 * i, 1000 * i + 50, size=50),  # churn
        ])
        for v in batch.tolist():
            true[v] = true.get(v, 0) + 1
        sk.update(batch)
        assert len(sk.counts) <= sk.k
    # the heavy key survives every tied shed and its count is a lower
    # bound on the true frequency (the Misra-Gries guarantee)
    assert 77 in sk.counts
    assert sk.counts[77] <= true[77]
    assert true[77] - sk.counts[77] <= sk.error_bound()
    # every surviving counter is a lower bound
    for v, c in sk.counts.items():
        assert c <= true[v]


def test_stored_stats_distinct_tightened_by_range_bound():
    """Summed per-chunk distinct counts overcount keys repeated across
    chunks; for integer columns the zone-map value range is a second
    sound upper bound (satellite: planner-stats sweep). A foreign-key
    column with 10 values over many chunks must not report 10x that."""
    import tempfile

    from repro.core import nrc as N
    from repro.storage import StorageCatalog, table_stats

    ty = {"R": N.bag(N.tuple_t(fk=N.INT, x=N.REAL))}
    rows = [{"fk": (i % 10) + 1, "x": float(i) + 0.5}
            for i in range(320)]
    with tempfile.TemporaryDirectory() as td:
        cat = StorageCatalog(td)
        cat.writer("d", ty, chunk_rows=32).append({"R": rows})
        st = table_stats(cat.open("d"))["R__F"]
    # 10 chunks x 10 distinct sums to 100; the range bound [1, 10]
    # tightens it to the true count
    assert st.distinct["fk"] == 10
    # float columns get no range bound (infinitely many values in any
    # interval) — only the row-count clamp applies
    assert st.distinct["x"] == 320


# ---------------------------------------------------------------------------
# compile integration: cost_mode plumbing
# ---------------------------------------------------------------------------

def test_compile_program_cost_mode_annotates_and_matches_off():
    from repro.core import codegen as CG
    from repro.core import materialization as M
    from repro.core import nrc as N

    types = {"R": N.bag(N.tuple_t(a=N.INT, b=N.INT))}
    R = N.Var("R", types["R"])
    q = N.for_in("x", R, lambda x: N.Singleton(N.record(a=x.a, b=x.b)))
    prog = N.Program([N.Assignment("Q", q)])
    sp = M.shred_program(prog, types, domain_elimination=True)
    cp_off = CG.compile_program(sp, cost_mode="off")
    cp_on = CG.compile_program(sp, cost_mode="auto")
    assert cp_off.estimates == {}
    assert set(cp_on.estimates) == {n for n, _ in cp_on.plans}
    for _, p in cp_on.plans:
        for sub in P._walk_plan(p):
            assert hasattr(sub, "est_rows")
    rows = [{"a": 1, "b": 2}, {"a": 3, "b": 4}]
    env = CG.columnar_shred_inputs({"R": rows}, types)
    o1 = CG.jit_program(cp_off)(env)
    o2 = CG.jit_program(cp_on)(env)
    for k in o1:
        assert np.array_equal(np.asarray(o1[k].valid),
                              np.asarray(o2[k].valid))
        for c in o1[k].data:
            assert np.array_equal(np.asarray(o1[k].data[c]),
                                  np.asarray(o2[k].data[c]))


def test_query_service_cost_mode_caches_estimates():
    from repro.core import nrc as N
    from repro.serve import QueryService

    types = {"R": N.bag(N.tuple_t(a=N.INT, b=N.INT))}
    R = N.Var("R", types["R"])
    q = N.for_in("x", R, lambda x: N.Singleton(N.record(a=x.a)))
    prog = N.Program([N.Assignment("Q", q)])
    svc = QueryService(types, cost_mode="auto", skew_partitions=8)
    env = svc.shred_inputs({"R": [{"a": 1, "b": 2}, {"a": 3, "b": 4}]})
    svc.execute(prog, env)
    (entry,) = svc._cache.values()
    assert entry.estimates and set(entry.estimates) == \
        {n for n, _ in entry.cp.plans}
    # warm call: cache hit, the snapshot is reused (no recompile)
    svc.execute(prog, env)
    assert svc.stats["hits"] == 1 and svc.stats["misses"] == 1
