"""Hypothesis property tests on the system's invariants.

Central property: for random nested databases and the benchmark query
family, the shredded route (shred -> materialize -> execute -> unshred)
equals direct NRC evaluation; value shredding round-trips; columnar ops
match their Python semantics."""

import numpy as np
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import codegen as CG
from repro.core import interpreter as I
from repro.core import materialization as M
from repro.core import nrc as N
from repro.columnar.table import FlatBag
from repro.exec import ops as X

from helpers import COP_T, INPUT_TYPES, PART_T, running_example_query


# -- strategies -------------------------------------------------------------

@st.composite
def cop_db(draw):
    n_parts = draw(st.integers(1, 8))
    parts = [{"pid": i, "pname": 100 + i,
              "price": float(draw(st.integers(1, 9)))}
             for i in range(1, n_parts + 1)]
    n_cust = draw(st.integers(0, 5))
    cops = []
    for c in range(n_cust):
        n_ord = draw(st.integers(0, 3))
        orders = []
        for o in range(n_ord):
            n_it = draw(st.integers(0, 4))
            items = [{"pid": draw(st.integers(1, n_parts + 2)),  # some misses
                      "qty": float(draw(st.integers(1, 5)))}
                     for _ in range(n_it)]
            orders.append({"odate": 20200000 + o, "oparts": items})
        cops.append({"cname": 1000 + c, "corders": orders})
    return {"COP": cops, "Part": parts}


@settings(max_examples=25, deadline=None)
@given(cop_db(), st.booleans())
def test_shred_equals_direct(db, domain_elim):
    q = running_example_query()
    direct = I.eval_expr(q, db)
    prog = N.Program([N.Assignment("Q", q)])
    sp = M.shred_program(prog, INPUT_TYPES, domain_elimination=domain_elim)
    env = M.shredded_input_env(db, INPUT_TYPES)
    env = I.eval_program(sp.program, env)
    got = M.unshred_from_env(env, sp.manifests["Q"])
    assert I.bags_equal(direct, got)


@settings(max_examples=25, deadline=None)
@given(cop_db())
def test_value_shred_roundtrip(db):
    shredded = I.shred_value(db["COP"], COP_T, root="COP")
    back = I.unshred_value(shredded, COP_T)
    assert I.bags_equal(db["COP"], back)


# -- columnar op semantics ----------------------------------------------------

@st.composite
def keyed_rows(draw):
    n = draw(st.integers(1, 24))
    rows = [{"k": draw(st.integers(0, 6)), "v": float(draw(st.integers(0, 9)))}
            for _ in range(n)]
    return rows


@settings(max_examples=30, deadline=None)
@given(keyed_rows(), st.integers(0, 8))
def test_sum_by_matches_python(rows, extra_cap):
    bag = FlatBag.from_rows(rows, {"k": "int", "v": "real"},
                            capacity=len(rows) + extra_cap)
    out = X.sum_by(bag, ("k",), ("v",)).to_rows()
    want = {}
    for r in rows:
        want[r["k"]] = want.get(r["k"], 0.0) + r["v"]
    got = {r["k"]: r["v"] for r in out}
    assert got == want


@settings(max_examples=30, deadline=None)
@given(keyed_rows())
def test_dedup_matches_python(rows):
    bag = FlatBag.from_rows(rows, {"k": "int", "v": "real"})
    out = X.dedup(bag, ("k", "v")).to_rows()
    want = {(r["k"], r["v"]) for r in rows}
    got = {(r["k"], r["v"]) for r in out}
    assert got == want and len(out) == len(want)


@settings(max_examples=30, deadline=None)
@given(keyed_rows(), st.integers(1, 6))
def test_fk_join_matches_python(rows, n_right):
    right_rows = [{"k": i, "w": float(i * 10)} for i in range(n_right)]
    left = FlatBag.from_rows(rows, {"k": "int", "v": "real"})
    right = FlatBag.from_rows(right_rows, {"k": "int", "w": "real"})
    out = X.fk_join(left, right, ("k",), ("k",), how="inner").to_rows()
    want = sorted((r["k"], r["v"], float(r["k"] * 10))
                  for r in rows if r["k"] < n_right)
    got = sorted((r["k"], r["v"], r["w"]) for r in out)
    assert got == want


@settings(max_examples=20, deadline=None)
@given(keyed_rows(), st.integers(1, 5))
def test_general_join_matches_python(rows, n_right):
    # right side with duplicate keys (M:N)
    right_rows = [{"k": i % 3, "w": float(i)} for i in range(n_right)]
    left = FlatBag.from_rows(rows, {"k": "int", "v": "real"})
    right = FlatBag.from_rows(right_rows, {"k": "int", "w": "real"})
    want = sorted((l["k"], l["v"], r["w"])
                  for l in rows for r in right_rows if l["k"] == r["k"])
    cap = max(len(want), 1)
    out, overflow = X.general_join(left, right, ("k",), ("k",), cap)
    got = sorted((r["k"], r["v"], r["w"]) for r in out.to_rows())
    assert int(overflow) == 0
    assert got == want


@settings(max_examples=20, deadline=None)
@given(keyed_rows())
def test_nest_level_partitions_rows(rows):
    bag = FlatBag.from_rows(rows, {"k": "int", "v": "real"})
    parents, children = X.nest_level(bag, ("k",), ("v",), "lbl")
    prows = parents.to_rows()
    crows = children.to_rows()
    assert {p["k"] for p in prows} == {r["k"] for r in rows}
    # every child's label maps to exactly one parent's key group
    lbl_to_k = {p["lbl"]: p["k"] for p in prows}
    got = sorted((lbl_to_k[c["lbl"]], c["v"]) for c in crows)
    want = sorted((r["k"], r["v"]) for r in rows)
    assert got == want
