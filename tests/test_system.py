"""End-to-end behaviour of the paper's system: shredding + materialization
+ both execution routes, validated against the pure-Python oracle."""

import pytest

from repro.core import codegen as CG
from repro.core import interpreter as I
from repro.core import materialization as M
from repro.core import nrc as N
from repro.core.materialization import mat_input_name
from repro.core.unnesting import Catalog, compile_standard

from helpers import (COP_T, INPUT_TYPES, PART_T, gen_cop, gen_parts,
                     running_example_query)

CATALOG = Catalog(unique_keys={"Part__F": ("pid",)})


@pytest.fixture(scope="module")
def data():
    return {"COP": gen_cop(n_cust=12, seed=3), "Part": gen_parts()}


@pytest.fixture(scope="module")
def direct(data):
    return I.eval_expr(running_example_query(),
                       {"COP": data["COP"], "Part": data["Part"]})


def _shred_run_interpreter(data, domain_elim):
    prog = N.Program([N.Assignment("Q", running_example_query())])
    sp = M.shred_program(prog, INPUT_TYPES, domain_elimination=domain_elim)
    env = M.shredded_input_env(data, INPUT_TYPES)
    env = I.eval_program(sp.program, env)
    return M.unshred_from_env(env, sp.manifests["Q"])


@pytest.mark.parametrize("domain_elim", [False, True])
def test_shredded_interpreter_route(data, direct, domain_elim):
    result = _shred_run_interpreter(data, domain_elim)
    assert I.bags_equal(direct, result)


@pytest.mark.parametrize("domain_elim", [True, False])
def test_shredded_columnar_route(data, direct, domain_elim):
    prog = N.Program([N.Assignment("Q", running_example_query())])
    sp = M.shred_program(prog, INPUT_TYPES, domain_elimination=domain_elim)
    cp = CG.compile_program(sp, CATALOG)
    env = CG.columnar_shred_inputs(data, INPUT_TYPES)
    env = CG.run_flat_program(cp, env)
    man = sp.manifests["Q"]
    parts = {(): env[man.top]}
    for path, name in man.dicts.items():
        parts[path] = env[name]
    result = CG.parts_to_rows(parts, running_example_query().ty)
    assert I.bags_equal(direct, result)


def test_standard_columnar_route(data, direct):
    q = running_example_query()
    splan = compile_standard(q, input_roots={"COP": COP_T},
                             flat_inputs={"Part": PART_T},
                             parts_name=mat_input_name, catalog=CATALOG)
    env = CG.columnar_shred_inputs(data, INPUT_TYPES)
    parts = CG.run_standard(splan, env)
    result = CG.parts_to_rows(parts, q.ty)
    assert I.bags_equal(direct, result)


def test_domain_elimination_produces_localized_aggregation():
    """The paper's Example 6 extension: with domain elimination, the leaf
    dictionary is computed by a sumBy keyed on (label, pname) directly
    over the input dictionary — no label-domain pass."""
    prog = N.Program([N.Assignment("Q", running_example_query())])
    sp = M.shred_program(prog, INPUT_TYPES, domain_elimination=True)
    names = sp.program.names()
    assert not any(n.startswith("LabDomain") for n in names)
    leaf = sp.program.get("Q__D_corders_oparts").expr
    assert isinstance(leaf, N.SumBy)
    assert leaf.keys[0] == "label"

    sp2 = M.shred_program(prog, INPUT_TYPES, domain_elimination=False)
    assert any(n.startswith("LabDomain") for n in sp2.program.names())


def test_nested_to_flat_query(data):
    """sumBy at top level (nested-to-flat family)."""
    COP = N.Var("COP", COP_T)
    Part = N.Var("Part", PART_T)
    q = N.SumBy(
        N.for_in("cop", COP, lambda cop:
            N.for_in("co", cop.corders, lambda co:
                N.for_in("op", co.oparts, lambda op:
                    N.for_in("p", Part, lambda p:
                        N.IfThen(op.pid.eq(p.pid),
                                 N.Singleton(N.record(
                                     cname=cop.cname,
                                     total=op.qty * p.price))))))),
        keys=("cname",), values=("total",))
    direct = I.eval_expr(q, data)
    splan = compile_standard(q, input_roots={"COP": COP_T},
                             flat_inputs={"Part": PART_T},
                             parts_name=mat_input_name, catalog=CATALOG)
    env = CG.columnar_shred_inputs(data, INPUT_TYPES)
    parts = CG.run_standard(splan, env)
    got = parts[()].to_rows()
    assert I.bags_equal(direct, got)


def test_pipeline_of_queries(data):
    """Two-step pipeline: the shredded output of Q1 feeds Q2 (the paper's
    sequence-of-transformations motivation) — no unshredding in between."""
    COP = N.Var("COP", COP_T)
    q1 = N.for_in("cop", COP, lambda cop: N.Singleton(N.record(
        cname=cop.cname,
        corders=N.for_in("co", cop.corders, lambda co:
            N.Singleton(N.record(odate=co.odate,
                                 oparts=co.oparts))))))
    Q1 = N.Var("Q1", q1.ty)
    q2 = N.SumBy(
        N.for_in("x", Q1, lambda x:
            N.for_in("co", x.corders, lambda co:
                N.for_in("op", co.oparts, lambda op:
                    N.Singleton(N.record(cname=x.cname, qty=op.qty))))),
        keys=("cname",), values=("qty",))
    prog = N.Program([N.Assignment("Q1", q1), N.Assignment("Q2", q2)])
    sp = M.shred_program(prog, INPUT_TYPES, domain_elimination=True)
    env = M.shredded_input_env(data, INPUT_TYPES)
    env = I.eval_program(sp.program, env)
    got = M.unshred_from_env(env, sp.manifests["Q2"])
    want = I.eval_program(prog, dict(data))["Q2"]
    assert I.bags_equal(want, got)


def test_empty_inner_bags_preserved(direct, data):
    """Customers with no orders / orders with no parts survive both
    routes (the paper's Challenge-1 correctness pitfall)."""
    empties = [r for r in direct if r["corders"] == []]
    cops = [c for c in data["COP"] if not c["corders"]]
    assert len(empties) == len(cops)
