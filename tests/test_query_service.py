"""QueryService plan cache: hit on re-invocation with different
parameter values (zero retracing), miss on schema / capacity-class
change, correctness parity with run_flat_program, and vmapped batch
execution."""

import numpy as np
import pytest

from repro.core import codegen as CG
from repro.core import interpreter as I
from repro.core import materialization as M
from repro.core import nrc as N
from repro.core.unnesting import Catalog
from repro.serve import QueryService
from repro.serve.query_service import _class_capacity, lift_program

PART_T = N.bag(N.tuple_t(pid=N.INT, pname=N.INT, price=N.REAL))
ORD_T = N.bag(N.tuple_t(odate=N.INT,
                        oparts=N.bag(N.tuple_t(pid=N.INT, qty=N.REAL))))
INPUT_TYPES = {"Ord": ORD_T, "Part": PART_T}
CATALOG = Catalog(unique_keys={"Part__F": ("pid",)})


def family(min_price: float) -> N.Program:
    Part = N.Var("Part", PART_T)
    Ord = N.Var("Ord", ORD_T)

    def tops(x):
        inner = N.for_in("op", x.oparts, lambda op:
            N.for_in("p", Part, lambda p:
                N.IfThen(N.BoolOp("&&", op.pid.eq(p.pid),
                                  p.price.ge(N.Const(min_price, N.REAL))),
                         N.Singleton(N.record(pname=p.pname,
                                              total=op.qty * p.price)))))
        return N.SumBy(inner, keys=("pname",), values=("total",))

    q = N.for_in("x", Ord, lambda x: N.Singleton(N.record(
        odate=x.odate, tops=tops(x))))
    return N.Program([N.Assignment("Q", q)])


def gen_data(n_orders=10, seed=0, max_items=4):
    rng = np.random.RandomState(seed)
    orders = [{"odate": 20200000 + i,
               "oparts": [{"pid": int(rng.randint(1, 10)),
                           "qty": float(rng.randint(1, 5))}
                          for _ in range(rng.randint(0, max_items + 1))]}
              for i in range(n_orders)]
    parts = [{"pid": i, "pname": 100 + i,
              "price": float(rng.randint(1, 20))}
             for i in range(1, 11)]
    return {"Ord": orders, "Part": parts}


@pytest.fixture(scope="module")
def data():
    return gen_data()


@pytest.fixture()
def svc():
    return QueryService(INPUT_TYPES, catalog=CATALOG)


def test_lift_program_fingerprint_stable():
    a, va = lift_program(family(3.0))
    b, vb = lift_program(family(17.0))
    assert N.program_fingerprint(a) == N.program_fingerprint(b)
    assert va != vb and len(va) == len(vb)


def test_fingerprint_covers_union():
    """Bag unions are fingerprintable (a query-service entry point)."""
    Ord = N.Var("Ord", ORD_T)

    def flat(lo):
        return N.SumBy(
            N.for_in("x", Ord, lambda x:
                N.for_in("op", x.oparts, lambda op:
                    N.IfThen(op.qty.ge(N.Const(lo, N.REAL)),
                             N.Singleton(N.record(odate=x.odate,
                                                  qty=op.qty))))),
            keys=("odate",), values=("qty",))

    u = N.UnionE(flat(1.0), flat(3.0))
    a, va = lift_program(N.Program([N.Assignment("Q", u)]))
    b, vb = lift_program(N.Program([N.Assignment(
        "Q", N.UnionE(flat(2.0), flat(9.0)))]))
    assert N.program_fingerprint(a) == N.program_fingerprint(b)
    assert len(va) == len(vb) == 2


def test_cache_hit_with_different_parameters(svc, data):
    env = svc.shred_inputs(data)
    CG.reset_trace_stats()
    svc.execute(family(5.0), env)
    assert svc.stats == {"hits": 0, "misses": 1, "evictions": 0,
                         "batch_calls": 0}
    traces_cold = CG.TRACE_STATS.get("traces", 0)
    for th in (2.0, 9.0, 16.0):
        svc.execute(family(th), env)
    assert svc.stats["hits"] == 3 and svc.stats["misses"] == 1
    # the warm path performed ZERO retracing
    assert CG.TRACE_STATS.get("traces", 0) == traces_cold


def test_parity_with_run_flat_program(svc, data):
    """Warm cached invocations match run_flat_program bit-for-bit (same
    class capacities) and the oracle on nested rows."""
    env = svc.shred_inputs(data)
    svc.execute(family(5.0), env)            # populate cache
    for th in (5.0, 11.0, 2.0):
        prog = family(th)
        out = svc.execute(prog, env)
        sp = M.shred_program(prog, INPUT_TYPES, domain_elimination=True)
        cp = CG.compile_program(sp, CATALOG)
        ref_env = CG.columnar_shred_inputs(data, INPUT_TYPES)
        ref_env = {k: b.resize(_class_capacity(b.capacity))
                   for k, b in ref_env.items()}
        ref = CG.run_flat_program(cp, ref_env)
        man = sp.manifests["Q"]
        for name in [man.top] + list(man.dicts.values()):
            a, b = out[name], ref[name]
            assert np.array_equal(np.asarray(a.valid),
                                  np.asarray(b.valid)), (th, name)
            for c in b.data:
                assert np.array_equal(np.asarray(a.data[c]),
                                      np.asarray(b.data[c])), (th, name, c)
        rows = svc.unshred(prog, env, out, "Q")
        direct = I.eval_expr(prog.assignments[0].expr, data)
        assert I.bags_equal(direct, rows), th


def test_cache_miss_on_capacity_class_change(svc, data):
    env = svc.shred_inputs(data)
    svc.execute(family(5.0), env)
    assert svc.stats["misses"] == 1
    # 30x the rows: different power-of-two class => miss
    big = dict(data, Ord=data["Ord"] * 30)
    env_big = svc.shred_inputs(big)
    svc.execute(family(5.0), env_big)
    assert svc.stats["misses"] == 2
    # same class again => hit
    svc.execute(family(7.0), env_big)
    assert svc.stats["misses"] == 2 and svc.stats["hits"] >= 1


def test_cache_hit_within_capacity_class(svc):
    """Row-count jitter inside one power-of-two class reuses the
    executable (bags are padded up to the class capacity)."""
    svc.execute(family(5.0), svc.shred_inputs(gen_data(10, seed=1)))
    assert svc.stats["misses"] == 1
    # same order count, different item draw -> same class caps
    data2 = gen_data(10, seed=1)
    data2["Ord"][0]["oparts"] = data2["Ord"][0]["oparts"][:1]
    env2 = svc.shred_inputs(data2)
    svc.execute(family(8.0), env2)
    assert svc.stats["misses"] == 1 and svc.stats["hits"] == 1


def test_cache_miss_on_schema_change(svc, data):
    env = svc.shred_inputs(data)
    svc.execute(family(5.0), env)
    assert svc.stats["misses"] == 1
    # widen one bag's schema: dtype/column fingerprint changes => miss
    env2 = dict(env)
    env2["Part__F"] = env["Part__F"].with_columns(
        extra=env["Part__F"].col("pid") * 2)
    svc.execute(family(5.0), env2)
    assert svc.stats["misses"] == 2


def test_structural_change_is_a_miss(svc, data):
    env = svc.shred_inputs(data)
    svc.execute(family(5.0), env)
    # different comparison operator => different structure
    Part = N.Var("Part", PART_T)
    Ord = N.Var("Ord", ORD_T)
    q = N.for_in("x", Ord, lambda x: N.Singleton(N.record(
        odate=x.odate,
        tops=N.SumBy(
            N.for_in("op", x.oparts, lambda op:
                N.for_in("p", Part, lambda p:
                    N.IfThen(N.BoolOp("&&", op.pid.eq(p.pid),
                                      p.price.le(N.Const(5.0, N.REAL))),
                             N.Singleton(N.record(pname=p.pname,
                                                  total=op.qty * p.price))))),
            keys=("pname",), values=("total",)))))
    svc.execute(N.Program([N.Assignment("Q", q)]), env)
    assert svc.stats["misses"] == 2


def test_execute_many_batches_one_family(svc, data):
    env = svc.shred_inputs(data)
    ths = (3.0, 7.0, 15.0)
    outs = svc.execute_many([family(t) for t in ths], env)
    assert len(outs) == len(ths)
    for t, out in zip(ths, outs):
        single = svc.execute(family(t), env)
        for name in single:
            a, b = out[name], single[name]
            assert np.array_equal(np.asarray(a.valid),
                                  np.asarray(b.valid)), (t, name)
            for c in b.data:
                assert np.array_equal(np.asarray(a.data[c]),
                                      np.asarray(b.data[c])), (t, name, c)


def test_execute_many_rejects_mixed_families(svc, data):
    env = svc.shred_inputs(data)
    Ord = N.Var("Ord", ORD_T)
    flat = N.SumBy(
        N.for_in("x", Ord, lambda x:
            N.for_in("op", x.oparts, lambda op:
                N.Singleton(N.record(odate=x.odate, qty=op.qty)))),
        keys=("odate",), values=("qty",))
    other = N.Program([N.Assignment("Q", flat)])
    with pytest.raises(AssertionError, match="family"):
        svc.execute_many([family(3.0), other], env)


def test_eviction(data):
    svc = QueryService(INPUT_TYPES, catalog=CATALOG, max_entries=2)
    env = svc.shred_inputs(data)
    svc.execute(family(5.0), env)
    svc.execute(N.Program([N.Assignment(
        "Q", family(5.0).assignments[0].expr)]), env)  # same => hit
    assert svc.stats["misses"] == 1
