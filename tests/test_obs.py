"""Unified telemetry: metrics registry, span tracer, EXPLAIN ANALYZE,
and the observed-stats feedback loop.

The differential acceptance mirrors the repo's seed-style invariant:
turning the tracer ON must not change a single output bit and must not
cost a single extra retrace (spans inside jitted code are host-side and
fire at trace time only)."""

import json

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import codegen as CG
from repro.core import nrc as N
from repro.core import plans as P
from repro.core.skew import TableStats, decide_heavy_keys
from repro.obs import (REGISTRY, TRACER, MetricsRegistry, StatsFeedback,
                       explain_analyze, metrics_scope,
                       record_observed_stats, span, tracing)
from repro.serve.query_service import QueryService

from helpers import (INPUT_TYPES, gen_cop, gen_parts,
                     running_example_query)


def _program():
    return N.Program([N.Assignment("Q", running_example_query())])


def _env():
    return CG.columnar_shred_inputs(
        {"Part": gen_parts(n=20, seed=0),
         "COP": gen_cop(6, 3, 4, 20, seed=1)}, INPUT_TYPES)


# ---------------------------------------------------------------------------
# MetricsRegistry
# ---------------------------------------------------------------------------

def test_registry_counters_gauges_and_views():
    r = MetricsRegistry()
    r.inc("sort.lexsort")
    r.inc("sort.lexsort", 2)
    r.set_gauge("shuffle.size_used_j0", 96)
    assert r.get("sort.lexsort") == 3
    assert r.get("shuffle.size_used_j0") == 96
    assert r.get("missing", -1) == -1

    # domain views behave like the dicts they replaced
    sort = r.view("sort")
    assert sort["lexsort"] == 3
    assert dict(sort) == {"lexsort": 3}
    sort["lexsort"] = 0
    sort["build_reuse"] = sort.get("build_reuse", 0) + 1
    assert r.get("sort.lexsort") == 0
    assert "build_reuse" in sort and len(sort) == 2
    del sort["build_reuse"]
    assert "build_reuse" not in sort
    sort.clear()
    assert dict(sort) == {} and r.get("shuffle.size_used_j0") == 96

    r.reset()
    assert r.snapshot() == {}


def test_engine_stats_names_are_registry_views():
    from repro.exec import ops as X
    from repro.exec import dist as D
    from repro.storage import reader as R
    X.SORT_STATS["lexsort"] = 7
    assert REGISTRY.get("sort.lexsort") == 7
    D.SHUFFLE_STATS["exchanges"] = 2
    assert REGISTRY.get("shuffle.exchanges") == 2
    R.STORAGE_STATS["parts_loaded"] = 1
    assert REGISTRY.get("storage.parts_loaded") == 1
    # the autouse fixture wipes these between tests — the historical
    # per-site SHUFFLE_STATS key leakage cannot recur
    assert CG.TRACE_STATS.get("traces", 0) == 0


def test_metrics_scope_nested_deltas():
    REGISTRY.inc("eval.join", 5)
    with metrics_scope() as outer:
        REGISTRY.inc("eval.join", 2)
        with metrics_scope() as inner:
            REGISTRY.inc("eval.join")
            REGISTRY.inc("eval.scan", 4)
        assert inner.get("eval.join") == 1
        assert inner.get("eval.scan") == 4
        REGISTRY.inc("eval.join")
    assert outer.get("eval.join") == 4      # 2 + 1 + 1, not the base 5
    assert outer.get("eval.scan") == 4
    assert outer.get("eval.never", 0) == 0
    assert REGISTRY.get("eval.join") == 9


def test_histogram_percentiles_match_numpy():
    rng = np.random.RandomState(0)
    samples = np.exp(rng.normal(3.0, 1.2, size=5000))   # lognormal ms
    r = MetricsRegistry()
    for v in samples:
        r.observe("lat", float(v))
    for q in (50, 90, 95, 99):
        got = r.percentile("lat", q)
        want = float(np.percentile(samples, q))
        assert abs(got - want) / want < 0.10, (q, got, want)
    ps = r.percentiles("lat")
    assert ps["p50"] <= ps["p95"] <= ps["p99"]
    assert np.isfinite(list(ps.values())).all()


def test_histogram_edge_cases():
    r = MetricsRegistry()
    assert np.isnan(r.percentile("empty", 50))
    r.observe("one", 42.0)
    assert r.percentile("one", 50) == pytest.approx(42.0, rel=0.1)
    r.observe("z", 0.0)
    r.observe("z", -1.0)
    assert r.percentile("z", 50) == 0.0


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------

def test_span_tree_and_chrome_export(tmp_path):
    with tracing(reset=True):
        with span("outer", kind="t"):
            with span("inner", i=0):
                pass
            with span("inner", i=1):
                pass
    roots = TRACER.tree()
    assert len(roots) == 1 and roots[0]["name"] == "outer"
    assert [c["name"] for c in roots[0]["children"]] == ["inner", "inner"]
    assert roots[0]["ms"] >= 0
    events = TRACER.chrome_trace()
    assert len(events) == 3
    for ev in events:
        assert ev["ph"] == "X" and "ts" in ev and "dur" in ev
    path = TRACER.save(str(tmp_path / "trace.json"))
    blob = json.loads(open(path).read())
    assert len(blob["traceEvents"]) == 3 and blob["tree"]


def test_spans_disabled_record_nothing():
    assert not TRACER.enabled
    with span("ghost", x=1) as sp:
        sp.attrs["y"] = 2       # writable sink, discarded
    assert TRACER.spans() == []


def test_unbalanced_exception_unwinds_spans():
    with tracing(reset=True):
        with pytest.raises(RuntimeError):
            with span("outer"):
                with span("inner"):
                    raise RuntimeError("boom")
    # both spans closed despite the unwind; durations recorded
    assert TRACER.span_names().count("outer") == 1
    for sp in TRACER.spans():
        assert sp.dur is not None


# ---------------------------------------------------------------------------
# differential: telemetry must not change results or cost retraces
# ---------------------------------------------------------------------------

def test_tracing_is_bit_identical_and_zero_retrace():
    svc = QueryService(INPUT_TYPES)
    env = _env()
    base = svc.execute(_program(), env)
    t_cold = CG.TRACE_STATS.get("traces", 0)
    warm_off = svc.execute(_program(), env)
    assert CG.TRACE_STATS.get("traces", 0) == t_cold

    with tracing(reset=True):
        warm_on = svc.execute(_program(), env)
        names = TRACER.span_names()
    # enabling the tracer on a WARM family: no retrace, same bits
    assert CG.TRACE_STATS.get("traces", 0) == t_cold
    assert "query.execute" in names
    assert "compile" not in names           # warm: nothing compiled
    for out in (warm_off, warm_on):
        for k in base:
            assert np.array_equal(np.asarray(base[k].valid),
                                  np.asarray(out[k].valid))
            for c in base[k].columns:
                assert np.array_equal(np.asarray(base[k].col(c)),
                                      np.asarray(out[k].col(c)))


def test_cold_compile_emits_compile_spans():
    svc = QueryService(INPUT_TYPES)
    env = _env()
    with tracing(reset=True):
        svc.execute(_program(), env)
        names = TRACER.span_names()
    assert "query.execute" in names and "query.compile" in names
    assert "compile" in names               # plan + xla_trace spans


# ---------------------------------------------------------------------------
# EXPLAIN ANALYZE (local path; the dist path gates in `make obs-smoke`)
# ---------------------------------------------------------------------------

def test_explain_analyze_local_annotations():
    res = explain_analyze(_program(), _env(), INPUT_TYPES)
    assert not res.distributed and res.total_ms > 0
    scans = [n for n in res.nodes() if "Scan" in n.op]
    gammas = res.find("SumAggP") + res.find("GroupAggP")
    assert scans and gammas
    for node in res.nodes():
        assert node.rows_out is not None
        if node.children:
            assert node.rows_in == sum(c.rows_out
                                       for c in node.children)
    text = res.pretty()
    assert "EXPLAIN ANALYZE" in text and "rows=" in text
    assert "Gamma" in text or "Join" in text
    blob = res.to_json()
    assert blob["assignments"]
    assert blob["assignments"][0]["plan"]["op"]


def test_explain_analyze_accepts_bare_expr_and_infers_types():
    res = explain_analyze(running_example_query(), _env())
    assert any("Scan" in n.op for n in res.nodes()) and res.outputs


# ---------------------------------------------------------------------------
# feedback: measured rows into planner stats + footer round-trip
# ---------------------------------------------------------------------------

def test_feedback_rows_flow_into_table_stats():
    fb = StatsFeedback()
    env = _env()
    fb.record_env(env)
    assert fb.observed_rows("COP__F") == 6
    stats = {"COP__F": TableStats(rows=4096)}   # capacity-class guess
    fb.apply(stats)
    ts = stats["COP__F"]
    assert ts.effective_rows == 6 and ts.rows == 4096
    # heavy-key decisions read the measured rows, not the estimate:
    # 30 hits in 1000 estimated rows is light (fair share 125), but 30
    # in 100 MEASURED rows crosses the fair share (12.5) -> heavy
    ts2 = TableStats(rows=1000, heavy={"k": [(7, 30)]},
                     meters={"rows": 100})
    with_meters = decide_heavy_keys(ts2, "k", n_partitions=8)
    without = decide_heavy_keys(
        TableStats(rows=1000, heavy={"k": [(7, 30)]}), "k",
        n_partitions=8)
    assert with_meters == [7] and without == []


def test_feedback_imbalance_monotone_and_serializable(tmp_path):
    fb = StatsFeedback()
    ratio = fb.record_metrics("fam", {"part_max_j0": 30,
                                      "part_rows_j0": 60}, 4)
    assert ratio == pytest.approx(2.0)
    fb.record_metrics("fam", {"part_max_j0": 15, "part_rows_j0": 60}, 4)
    assert fb.imbalance_x100["fam"] == 200      # max, not latest
    p = str(tmp_path / "fb.json")
    fb.rows["X"] = 11
    fb.save(p)
    back = StatsFeedback.load(p)
    assert back.rows == fb.rows
    assert back.imbalance_x100 == fb.imbalance_x100


def test_observed_stats_footer_round_trip(tmp_path):
    from repro.storage import StorageCatalog
    data = {"Part": gen_parts(n=20, seed=0),
            "COP": gen_cop(6, 3, 4, 20, seed=1)}
    cat = StorageCatalog(str(tmp_path))
    ds = cat.write("shop", data, INPUT_TYPES)
    part = next(iter(ds.parts))
    est = ds.parts[part].stats().rows
    n = record_observed_stats(ds.dir, {part: {"rows": est + 5},
                                       "no_such_part": {"rows": 1}})
    assert n == 1
    ds2 = cat.open("shop", refresh=True)
    ts = ds2.parts[part].stats()
    assert ts.meters["rows"] == est + 5
    assert ts.effective_rows == est + 5 and ts.rows == est


def test_query_service_feedback_measures_on_cold_compile():
    fb = StatsFeedback()
    svc = QueryService(INPUT_TYPES, feedback=fb)
    env = _env()
    out = svc.execute(_program(), env)
    assert out and fb.rows                  # measured on the miss
    assert fb.observed_rows("COP__F") == 6
    rows_before = dict(fb.rows)
    svc.execute(_program(), env)            # warm: no re-measurement
    assert fb.rows == rows_before
