"""Distributed engine tests — run in a subprocess with 8 virtual devices
(XLA device count must be set before jax init; tests elsewhere keep the
default single device per the dry-run isolation rule)."""

import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(body: str) -> str:
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, %r)
        sys.path.insert(0, %r)
        import numpy as np
        import jax
        import repro
        from repro.core import nrc as N
        from repro.core import interpreter as I
        from repro.core import materialization as M
        from repro.core import codegen as CG
        from repro.core.plans import ExecSettings
        from repro.core.unnesting import Catalog
        from repro.exec.dist import device_mesh_1d, run_distributed
        from helpers import INPUT_TYPES, gen_cop, gen_parts, \
            running_example_query
    """) % (SRC, os.path.dirname(__file__)) + textwrap.dedent(body)
    res = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, f"STDOUT:{res.stdout}\nSTDERR:{res.stderr}"
    return res.stdout


@pytest.mark.slow
def test_distributed_shredded_route_matches_oracle():
    out = run_sub("""
        data = {"COP": gen_cop(n_cust=16, seed=2, zipf=0.6),
                "Part": gen_parts(29)}
        direct = I.eval_expr(running_example_query(), data)
        prog = N.Program([N.Assignment("Q", running_example_query())])
        sp = M.shred_program(prog, INPUT_TYPES, domain_elimination=True)
        cp = CG.compile_program(sp, Catalog(unique_keys={"Part__F": ("pid",)}))
        env = CG.columnar_shred_inputs(data, INPUT_TYPES)
        PN = 8
        env = {k: b.resize(((b.capacity + PN - 1)//PN)*PN)
               for k, b in env.items()}
        mesh = device_mesh_1d(PN)
        shuffles = {}
        for skew in (False, True):
            def fn(env_local, ctx):
                out_env = CG.run_flat_program(cp, env_local,
                                              ExecSettings(dist=ctx))
                man = sp.manifests["Q"]
                names = [man.top] + list(man.dicts.values())
                return {k: out_env[k] for k in names}
            out, metrics = run_distributed(fn, env, mesh,
                                           skew_default=skew,
                                           cap_factor=16.0)
            man = sp.manifests["Q"]
            parts = {(): out[man.top]}
            for path, name in man.dicts.items():
                parts[path] = out[name]
            result = CG.parts_to_rows(parts, running_example_query().ty)
            assert I.bags_equal(direct, result), f"skew={skew} mismatch"
            shuffles[skew] = metrics["shuffle_rows"]
        # the skew-aware join must shuffle strictly less on zipf data
        assert shuffles[True] < shuffles[False], shuffles
        print("OK", shuffles)
    """)
    assert "OK" in out


@pytest.mark.slow
def test_exchange_preserves_rows_and_detects_overflow():
    out = run_sub("""
        from repro.columnar.table import FlatBag
        import jax.numpy as jnp
        rows = [{"k": i % 13, "v": float(i)} for i in range(64)]
        bag = FlatBag.from_rows(rows, {"k": "int", "v": "real"},
                                capacity=64)
        mesh = device_mesh_1d(8)
        def fn(env, ctx):
            return {"out": ctx.exchange(env["bag"], ("k",))}
        out, metrics = run_distributed(fn, {"bag": bag}, mesh,
                                       cap_factor=16.0)
        got = sorted((r["k"], r["v"]) for r in out["out"].to_rows())
        want = sorted((r["k"], r["v"]) for r in rows)
        assert got == want, (got, want)
        assert metrics["overflow_rows"] == 0
        # tight capacity must overflow (and count it) on skewed keys
        rows2 = [{"k": 0, "v": float(i)} for i in range(64)]
        bag2 = FlatBag.from_rows(rows2, {"k": "int", "v": "real"},
                                 capacity=64)
        out2, m2 = run_distributed(fn, {"bag": bag2}, mesh,
                                   cap_factor=1.0)
        assert m2["overflow_rows"] > 0
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_distributed_sum_by_and_dedup():
    out = run_sub("""
        from repro.columnar.table import FlatBag
        rows = [{"k": i % 5, "v": 1.0} for i in range(40)]
        bag = FlatBag.from_rows(rows, {"k": "int", "v": "real"},
                                capacity=40)
        mesh = device_mesh_1d(8)
        def fn(env, ctx):
            return {"s": ctx.sum_by(env["bag"], ("k",), ("v",)),
                    "d": ctx.dedup(env["bag"], ("k",))}
        out, metrics = run_distributed(fn, {"bag": bag}, mesh,
                                       cap_factor=16.0)
        s = {r["k"]: r["v"] for r in out["s"].to_rows()}
        assert s == {k: 8.0 for k in range(5)}, s
        d = sorted(r["k"] for r in out["d"].to_rows())
        assert d == list(range(5)), d
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_exchange_roundtrip_property():
    """Hypothesis property (via the tier-1 shim): the packed exchange
    preserves the multiset of valid rows for random dtypes / validity
    patterns, and overflows nothing at generous capacity."""
    out = run_sub("""
        import collections
        import jax.numpy as jnp
        import _hypothesis_shim as hyp
        st = hyp.strategies
        from repro.columnar.table import FlatBag
        mesh = device_mesh_1d(8)

        def fn(env, ctx):
            return {"out": ctx.exchange(env["bag"], ("k",))}

        @hyp.settings(max_examples=6, deadline=None)
        @hyp.given(st.integers(1, 12),
                   st.sampled_from(["int", "real", "string", "bool"]),
                   st.integers(0, 3), st.floats(0.2, 1.0))
        def check(n_keys, kind, seed, valid_frac):
            cap = 48
            rng = np.random.RandomState(seed)
            keys = jnp.asarray(rng.randint(0, n_keys, cap), jnp.int64)
            if kind == "int":
                v = jnp.asarray(rng.randint(-50, 50, cap), jnp.int64)
            elif kind == "real":
                v = jnp.asarray(rng.randn(cap), jnp.float64)
            elif kind == "string":
                v = jnp.asarray(rng.randint(0, 5, cap), jnp.int32)
            else:
                v = jnp.asarray(rng.randint(0, 2, cap), bool)
            valid = jnp.asarray(rng.rand(cap) < valid_frac)
            bag = FlatBag({"k": keys, "v": v}, valid)
            before = collections.Counter(
                (int(k), float(x)) for k, x, ok in
                zip(keys, v.astype(jnp.float64), valid) if ok)
            out, m = run_distributed(fn, {"bag": bag}, mesh,
                                     cap_factor=16.0)
            ob = out["out"]
            after = collections.Counter(
                (int(k), float(x)) for k, x, ok in
                zip(ob.col("k"), ob.col("v").astype(jnp.float64),
                    ob.valid) if ok)
            assert before == after, (kind, seed, before, after)
            assert m["overflow_rows"] == 0, m
            assert m["shuffle_collectives"] == 1, m

        check()
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_exchange_overflow_edge_and_adaptive():
    out = run_sub("""
        from repro.columnar.table import FlatBag
        rows = [{"k": 0, "v": float(i)} for i in range(64)]
        bag = FlatBag.from_rows(rows, {"k": "int", "v": "real"},
                                capacity=64)
        mesh = device_mesh_1d(8)
        def fn(env, ctx):
            return {"out": ctx.exchange(env["bag"], ("k",))}
        # bucket exactly equal to the per-sender count: everything fits
        out, m = run_distributed(fn, {"bag": bag}, mesh, cap_factor=8.0)
        assert m["overflow_rows"] == 0 and m["shuffle_rows"] == 64, m
        # one short: each of the 8 senders drops exactly one row
        out, m = run_distributed(fn, {"bag": bag}, mesh, cap_factor=7.0)
        assert m["overflow_rows"] == 8 and m["shuffle_rows"] == 56, m
        # adaptive capacity: starts undersized, regrows to the true max
        out, m = run_distributed(fn, {"bag": bag}, mesh, cap_factor=1.0,
                                 adaptive=True)
        got = sorted(r["v"] for r in out["out"].to_rows())
        assert got == [float(i) for i in range(64)], got
        assert m["overflow_rows"] == 0 and m["size_need_0"] == 8, m
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_exchange_elision_and_shuffle_stats():
    """Partitioning-aware elision: join -> sum_by on the same key moves
    probe rows across the wire exactly once; co-partitioned joins
    exchange neither side; legacy mode does neither optimization."""
    out = run_sub("""
        from repro.columnar.table import FlatBag
        L = FlatBag.from_rows([{"k": i % 7, "v": float(i)}
                               for i in range(64)],
                              {"k": "int", "v": "real"}, capacity=64)
        R = FlatBag.from_rows([{"k": i, "w": float(10 * i)}
                               for i in range(8)],
                              {"k": "int", "w": "real"}, capacity=8)
        mesh = device_mesh_1d(8)
        want = {}
        for i in range(64):
            want[i % 7] = want.get(i % 7, 0.0) + float(i)

        def fn(env, ctx):
            j = ctx.join(env["L"], env["R"], ("k",), ("k",))
            return {"out": ctx.sum_by(j, ("k",), ("v",),
                                      local_preagg=False)}

        for mode, n_ex, n_el in (("packed", 2, 1), ("legacy", 3, 0)):
            out, m = run_distributed(fn, {"L": L, "R": R}, mesh,
                                     cap_factor=16.0, shuffle_mode=mode)
            got = sorted((r["k"], r["v"]) for r in out["out"].to_rows())
            assert got == sorted(want.items()), (mode, got)
            assert m["exchanges"] == n_ex, (mode, m)
            assert m["exchanges_elided"] == n_el, (mode, m)
        # a pre-partitioned probe flows through sum_by AND dedup with no
        # further exchange: one wire crossing for the whole pipeline
        def fn2(env, ctx):
            a = ctx.exchange(env["L"], ("k",))
            s = ctx.sum_by(a, ("k",), ("v",), local_preagg=False)
            return {"out": ctx.dedup(s, ("k",))}
        out, m = run_distributed(fn2, {"L": L, "R": R}, mesh,
                                 cap_factor=16.0)
        got = sorted(r["k"] for r in out["out"].to_rows())
        assert got == list(range(7)), got
        assert m["exchanges"] == 1 and m["exchanges_elided"] == 2, m
        # co-partitioned join: neither side moves again
        def fn3(env, ctx):
            a = ctx.exchange(env["L"], ("k",))
            b = ctx.exchange(env["R"], ("k",))
            j = ctx.join(a, b, ("k",), ("k",))
            return {"out": ctx.sum_by(j, ("k",), ("v",),
                                      local_preagg=False)}
        out, m = run_distributed(fn3, {"L": L, "R": R}, mesh,
                                 cap_factor=16.0)
        got = sorted((r["k"], r["v"]) for r in out["out"].to_rows())
        assert got == sorted(want.items()), got
        assert m["exchanges"] == 2 and m["exchanges_elided"] == 3, m
        # routing reuse: exchanging the SAME bag on the same key twice
        # argsorts the destinations once (props.route_cache)
        from repro.exec import dist as D
        def fn4(env, ctx):
            a = ctx.exchange(env["L"], ("k",))
            b = ctx.exchange(env["L"], ("k",))
            return {"a": a, "b": b}
        out, m = run_distributed(fn4, {"L": L, "R": R}, mesh,
                                 cap_factor=16.0)
        assert m["exchanges"] == 2, m
        assert D.SHUFFLE_STATS.get("route_argsort", 0) == 1, \
            dict(D.SHUFFLE_STATS)
        assert D.SHUFFLE_STATS.get("route_reuse", 0) == 1, \
            dict(D.SHUFFLE_STATS)
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_exchange_packed_kernel_path():
    out = run_sub("""
        from repro.columnar.table import FlatBag
        rows = [{"k": i % 13, "v": float(i)} for i in range(64)]
        bag = FlatBag.from_rows(rows, {"k": "int", "v": "real"},
                                capacity=64)
        mesh = device_mesh_1d(8)
        def fn(env, ctx):
            return {"out": ctx.exchange(env["bag"], ("k",))}
        out, m = run_distributed(fn, {"bag": bag}, mesh, cap_factor=4.0,
                                 use_kernel=True)
        got = sorted((r["k"], r["v"]) for r in out["out"].to_rows())
        want = sorted((r["k"], r["v"]) for r in rows)
        assert got == want, (got, want)
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_heavy_key_detection():
    out = run_sub("""
        import jax.numpy as jnp
        from repro.core import skew as SK
        key = jnp.concatenate([jnp.full((900,), 7, jnp.int64),
                               jnp.arange(100, dtype=jnp.int64)])
        valid = jnp.ones((1000,), bool)
        hk = SK.heavy_keys_local(key, valid, sample=256, threshold=0.025)
        member = SK.is_member(jnp.asarray([7, 3], jnp.int64),
                              SK.merge_heavy(hk))
        assert bool(member[0]) and not bool(member[1])
        print("OK")
    """)
    assert "OK" in out
