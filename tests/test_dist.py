"""Distributed engine tests — run in a subprocess with 8 virtual devices
(XLA device count must be set before jax init; tests elsewhere keep the
default single device per the dry-run isolation rule)."""

import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(body: str) -> str:
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, %r)
        sys.path.insert(0, %r)
        import numpy as np
        import jax
        import repro
        from repro.core import nrc as N
        from repro.core import interpreter as I
        from repro.core import materialization as M
        from repro.core import codegen as CG
        from repro.core.plans import ExecSettings
        from repro.core.unnesting import Catalog
        from repro.exec.dist import device_mesh_1d, run_distributed
        from helpers import INPUT_TYPES, gen_cop, gen_parts, \
            running_example_query
    """) % (SRC, os.path.dirname(__file__)) + textwrap.dedent(body)
    res = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, f"STDOUT:{res.stdout}\nSTDERR:{res.stderr}"
    return res.stdout


@pytest.mark.slow
def test_distributed_shredded_route_matches_oracle():
    out = run_sub("""
        data = {"COP": gen_cop(n_cust=16, seed=2, zipf=0.6),
                "Part": gen_parts(29)}
        direct = I.eval_expr(running_example_query(), data)
        prog = N.Program([N.Assignment("Q", running_example_query())])
        sp = M.shred_program(prog, INPUT_TYPES, domain_elimination=True)
        cp = CG.compile_program(sp, Catalog(unique_keys={"Part__F": ("pid",)}))
        env = CG.columnar_shred_inputs(data, INPUT_TYPES)
        PN = 8
        env = {k: b.resize(((b.capacity + PN - 1)//PN)*PN)
               for k, b in env.items()}
        mesh = device_mesh_1d(PN)
        shuffles = {}
        for skew in (False, True):
            def fn(env_local, ctx):
                out_env = CG.run_flat_program(cp, env_local,
                                              ExecSettings(dist=ctx))
                man = sp.manifests["Q"]
                names = [man.top] + list(man.dicts.values())
                return {k: out_env[k] for k in names}
            out, metrics = run_distributed(fn, env, mesh,
                                           skew_default=skew,
                                           cap_factor=16.0)
            man = sp.manifests["Q"]
            parts = {(): out[man.top]}
            for path, name in man.dicts.items():
                parts[path] = out[name]
            result = CG.parts_to_rows(parts, running_example_query().ty)
            assert I.bags_equal(direct, result), f"skew={skew} mismatch"
            shuffles[skew] = metrics["shuffle_rows"]
        # the skew-aware join must shuffle strictly less on zipf data
        assert shuffles[True] < shuffles[False], shuffles
        print("OK", shuffles)
    """)
    assert "OK" in out


@pytest.mark.slow
def test_exchange_preserves_rows_and_detects_overflow():
    out = run_sub("""
        from repro.columnar.table import FlatBag
        import jax.numpy as jnp
        rows = [{"k": i % 13, "v": float(i)} for i in range(64)]
        bag = FlatBag.from_rows(rows, {"k": "int", "v": "real"},
                                capacity=64)
        mesh = device_mesh_1d(8)
        def fn(env, ctx):
            return {"out": ctx.exchange(env["bag"], ("k",))}
        out, metrics = run_distributed(fn, {"bag": bag}, mesh,
                                       cap_factor=16.0)
        got = sorted((r["k"], r["v"]) for r in out["out"].to_rows())
        want = sorted((r["k"], r["v"]) for r in rows)
        assert got == want, (got, want)
        assert metrics["overflow_rows"] == 0
        # tight capacity must overflow (and count it) on skewed keys
        rows2 = [{"k": 0, "v": float(i)} for i in range(64)]
        bag2 = FlatBag.from_rows(rows2, {"k": "int", "v": "real"},
                                 capacity=64)
        out2, m2 = run_distributed(fn, {"bag": bag2}, mesh,
                                   cap_factor=1.0)
        assert m2["overflow_rows"] > 0
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_distributed_sum_by_and_dedup():
    out = run_sub("""
        from repro.columnar.table import FlatBag
        rows = [{"k": i % 5, "v": 1.0} for i in range(40)]
        bag = FlatBag.from_rows(rows, {"k": "int", "v": "real"},
                                capacity=40)
        mesh = device_mesh_1d(8)
        def fn(env, ctx):
            return {"s": ctx.sum_by(env["bag"], ("k",), ("v",)),
                    "d": ctx.dedup(env["bag"], ("k",))}
        out, metrics = run_distributed(fn, {"bag": bag}, mesh,
                                       cap_factor=16.0)
        s = {r["k"]: r["v"] for r in out["s"].to_rows()}
        assert s == {k: 8.0 for k in range(5)}, s
        d = sorted(r["k"] for r in out["d"].to_rows())
        assert d == list(range(5)), d
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_heavy_key_detection():
    out = run_sub("""
        import jax.numpy as jnp
        from repro.core import skew as SK
        key = jnp.concatenate([jnp.full((900,), 7, jnp.int64),
                               jnp.arange(100, dtype=jnp.int64)])
        valid = jnp.ones((1000,), bool)
        hk = SK.heavy_keys_local(key, valid, sample=256, threshold=0.025)
        member = SK.is_member(jnp.asarray([7, 3], jnp.int64),
                              SK.merge_heavy(hk))
        assert bool(member[0]) and not bool(member[1])
        print("OK")
    """)
    assert "OK" in out
