"""Fault-tolerance substrate: checkpoint atomicity/integrity/resume,
elastic re-shard, watchdog, compression accuracy, optimizers."""

import os
import signal
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.train import checkpoint as CKPT
from repro.train import optim as O
from repro.train.elastic import TrainState, Watchdog, run_resumable


def small_tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.float32)}}


def test_checkpoint_roundtrip(tmp_path):
    d = str(tmp_path / "ck")
    tree = small_tree()
    CKPT.save(d, 7, tree, extra={"cursor": 3})
    got, manifest = CKPT.restore(d, template=tree)
    assert manifest["step"] == 7 and manifest["extra"]["cursor"] == 3
    np.testing.assert_array_equal(np.asarray(got["a"]),
                                  np.asarray(tree["a"]))


def test_checkpoint_atomicity_ignores_incomplete(tmp_path):
    d = str(tmp_path / "ck")
    tree = small_tree()
    CKPT.save(d, 1, tree)
    # simulate a crash mid-write of step 2: directory without .complete
    os.makedirs(os.path.join(d, "step_00000002"))
    assert CKPT.latest_step(d) == 1


def test_checkpoint_integrity_detection(tmp_path):
    d = str(tmp_path / "ck")
    CKPT.save(d, 1, small_tree())
    # corrupt the arrays file
    path = os.path.join(d, "step_00000001", "arrays.npz")
    data = dict(np.load(path))
    data["a"] = data["a"] + 1
    np.savez(path, **data)
    with pytest.raises(AssertionError, match="checksum"):
        CKPT.restore(d, template=small_tree())


def test_checkpoint_retention(tmp_path):
    d = str(tmp_path / "ck")
    for s in range(1, 6):
        CKPT.save(d, s, small_tree(), keep_last_k=2)
    kept = sorted(x for x in os.listdir(d) if x.startswith("step_"))
    assert kept == ["step_00000004", "step_00000005"]


def test_async_checkpointer(tmp_path):
    d = str(tmp_path / "ck")
    ck = CKPT.AsyncCheckpointer(d, keep_last_k=2)
    ck.save(10, small_tree())
    ck.wait()
    assert CKPT.latest_step(d) == 10


def test_run_resumable_resumes_after_interrupt(tmp_path):
    """Train 3 steps, 'crash', restart — resumes at step 3 with state."""
    d = str(tmp_path / "ck")
    cfg = O.OptConfig(kind="adamw", lr=0.1, warmup=1, total_steps=100)
    params = {"w": jnp.ones((4,), jnp.float32)}

    def train_step(params, opt_state, batch):
        def loss_fn(p):
            return jnp.sum((p["w"] - batch) ** 2)
        loss, g = jax.value_and_grad(loss_fn)(params)
        p2, s2 = O.apply_updates(cfg, params, g, opt_state)
        return p2, s2, {"loss": loss}

    def batch_fn(cursor, rng):
        return jnp.full((4,), float(cursor % 3), jnp.float32)

    st0 = TrainState(params, O.init_state(cfg, params), 0,
                     jax.random.PRNGKey(0), 0)
    st1 = run_resumable(train_step, st0, batch_fn, n_steps=3,
                        ckpt_dir=d, ckpt_every=2)
    assert st1.step == 3
    # restart "after a crash" — run_resumable restores from latest ckpt
    st2 = TrainState(params, O.init_state(cfg, params), 0,
                     jax.random.PRNGKey(0), 0)
    st2 = run_resumable(train_step, st2, batch_fn, n_steps=6,
                        ckpt_dir=d, ckpt_every=2)
    assert st2.step == 6
    assert st2.data_cursor == 6     # exact-once batch accounting


def test_watchdog_flags_stragglers():
    w = Watchdog(alpha=0.5, threshold=2.0)
    flagged = []
    w.on_straggler = lambda s, dt, ew: flagged.append(s)
    for s, dt in enumerate([1.0, 1.1, 0.9, 5.0, 1.0]):
        w.observe(s, dt)
    assert flagged == [3]
    assert w.slow_steps == 1


@pytest.mark.parametrize("kind", ["adamw", "adafactor"])
def test_optimizer_converges_quadratic(kind):
    cfg = O.OptConfig(kind=kind, lr=0.1, warmup=1, total_steps=500,
                      weight_decay=0.0)
    params = {"w": jnp.asarray([5.0, -3.0, 2.0])}
    state = O.init_state(cfg, params)
    target = jnp.asarray([1.0, 1.0, 1.0])
    for _ in range(150):
        g = {"w": 2 * (params["w"] - target)}
        params, state = O.apply_updates(cfg, params, g, state)
    assert float(jnp.max(jnp.abs(params["w"] - target))) < 0.3


def test_adafactor_memory_is_factored():
    cfg = O.OptConfig(kind="adafactor")
    params = {"w": jnp.zeros((64, 32))}
    st = O.init_state(cfg, params)
    assert st["f"]["w"]["vr"].shape == (64,)
    assert st["f"]["w"]["vc"].shape == (32,)


def test_quantize_roundtrip_error_bounded():
    from repro.train.compression import dequantize_int8, quantize_int8
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(1000), jnp.float32)
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s) - x))
    assert err.max() <= float(s) * 0.5 + 1e-6


def test_elastic_reshard_restore(tmp_path):
    """Checkpoint written under one topology restores onto another
    (shardings=None here — single device — exercising the API path)."""
    d = str(tmp_path / "ck")
    tree = small_tree()
    CKPT.save(d, 1, tree)
    from repro.train.elastic import reshard_restore
    got, _ = reshard_restore(d, tree, jax.tree.map(lambda _: None, tree))
    np.testing.assert_array_equal(np.asarray(got["b"]["c"]),
                                  np.asarray(tree["b"]["c"]))
