"""Sort-order-aware fused executor: physical properties, sort sharing,
plan ordering pass, and general_join overflow accounting.

The headline acceptance: a ``join -> sum_by -> nest_level`` pipeline on
shared keys sorts the probe side EXACTLY once (asserted through the
SORT_STATS hook), and produces the same answer as the unfused executor
(ORDER_AWARE=False recomputes everything per operator, seed-style)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.columnar.table import FlatBag
from repro.core import nrc as N
from repro.core import plans as P
from repro.exec import ops as X


def _mk_left(n=24, seed=0):
    rng = np.random.RandomState(seed)
    rows = [{"k": int(rng.randint(0, 6)), "g": int(rng.randint(0, 4)),
             "v": float(rng.randint(0, 9))} for _ in range(n)]
    return FlatBag.from_rows(rows, {"k": "int", "g": "int", "v": "real"},
                             capacity=n + 4), rows


def _mk_right(n=6):
    return FlatBag.from_rows([{"k": i, "w": float(i * 10)}
                              for i in range(n)],
                             {"k": "int", "w": "real"})


def _pipeline(left, right, use_kernel=False):
    j = X.fk_join(left, right, ("k",), ("k",), use_kernel=use_kernel)
    agg = X.sum_by(j, ("g", "k"), ("v", "w"), use_kernel=use_kernel)
    parents, children = X.nest_level(agg, ("g",), ("k", "v", "w"), "lbl",
                                     use_kernel=use_kernel)
    lbl = {r["lbl"]: r["g"] for r in parents.to_rows()}
    return sorted((lbl[r["lbl"]], r["k"], r["v"], r["w"])
                  for r in children.to_rows())


# -- acceptance: one probe-side sort for join -> sum_by -> nest_level --------

@pytest.mark.parametrize("use_kernel", [False, True])
def test_pipeline_sorts_probe_side_exactly_once(use_kernel):
    left, _ = _mk_left()
    right = _mk_right()
    fused = _pipeline(left, right, use_kernel=use_kernel)
    assert X.SORT_STATS.get("lexsort", 0) == 1, X.SORT_STATS
    assert X.SORT_STATS.get("sort_skipped", 0) >= 1, X.SORT_STATS
    # the one argsort is the (small) build side, never the probe side
    assert X.SORT_STATS.get("build_argsort", 0) <= 1, X.SORT_STATS

    with X.order_awareness(False):
        X.reset_sort_stats()
        unfused = _pipeline(_mk_left()[0], _mk_right(),
                            use_kernel=use_kernel)
        assert X.SORT_STATS.get("lexsort", 0) == 2  # sum_by + nest_level
    assert fused == unfused


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 40), st.integers(1, 8), st.integers(0, 5))
def test_fused_pipeline_matches_unfused(n, n_right, seed):
    left, _ = _mk_left(n, seed)
    right = _mk_right(n_right)
    fused = _pipeline(left, right)
    with X.order_awareness(False):
        unfused = _pipeline(_mk_left(n, seed)[0], _mk_right(n_right))
    assert fused == unfused


# -- physical props propagation ----------------------------------------------

def test_sum_by_delivers_sorted_by_keys():
    bag, _ = _mk_left()
    out = X.sum_by(bag, ("g", "k"), ("v",))
    assert out.props.sorted_by == ("g", "k")
    assert out.props.invalid_last
    # grouping by the PREFIX reuses the sort
    X.reset_sort_stats()
    X.sum_by(out, ("g",), ("v",))
    assert "lexsort" not in X.SORT_STATS


def test_mask_preserves_order_drops_invalid_last():
    bag, _ = _mk_left()
    out = X.sum_by(bag, ("k",), ("v",))
    masked = out.mask(out.col("v") > 3)
    assert masked.props.sorted_by == ("k",)
    assert not masked.props.invalid_last
    X.reset_sort_stats()
    X.dedup(masked, ("k",))           # still no sort needed
    assert "lexsort" not in X.SORT_STATS


def test_with_columns_overwrite_invalidates():
    bag, _ = _mk_left()
    out = X.sum_by(bag, ("k",), ("v",))
    kept = out.with_columns(extra=out.col("v") * 2)
    assert kept.props.sorted_by == ("k",)
    clobbered = out.with_columns(k=out.col("v").astype(jnp.int64))
    assert clobbered.props.sorted_by is None


def test_build_argsort_cached_across_joins():
    left, _ = _mk_left()
    right = _mk_right()
    X.reset_sort_stats()
    X.fk_join(left, right, ("k",), ("k",))
    X.fk_join(left, right, ("k",), ("k",))
    assert X.SORT_STATS.get("build_argsort", 0) == 1
    assert X.SORT_STATS.get("build_reuse", 0) == 1
    assert X.SORT_STATS.get("key_reuse", 0) >= 1   # probe key packed once


def test_sorted_build_side_skips_argsort():
    left, _ = _mk_left()
    # sum_by output is unique + sorted on its key: a free build side
    raw = FlatBag.from_rows([{"k": i % 5, "w": float(i)} for i in range(12)],
                            {"k": "int", "w": "real"})
    right = X.sum_by(raw, ("k",), ("w",))
    X.reset_sort_stats()
    X.fk_join(left, right, ("k",), ("k",))
    assert X.SORT_STATS.get("build_argsort", 0) == 0
    assert X.SORT_STATS.get("build_sort_skipped", 0) == 1


def test_general_join_preserves_probe_order():
    left = X.sum_by(_mk_left()[0], ("g", "k"), ("v",))
    right = _mk_right()
    out, _ = X.general_join(left, right, ("k",), ("k",), 64)
    assert out.props.sorted_by == ("g", "k")
    assert out.props.invalid_last


# -- plan-level ordering pass -------------------------------------------------

def _scan_plan(bag, alias):
    return P.ScanP(bag, alias)


def test_push_order_reorders_keys_for_prefix_sharing():
    # dedup(g) above sum_by(keys incl g): keys get reordered g-first
    agg = P.SumAggP(_scan_plan("L", "l"), keys=("l.k", "l.g"),
                    vals=("l.v",))
    plan = P.push_order(P.DeDupP(agg, cols=("l.g",)))
    assert isinstance(plan, P.DeDupP)
    assert plan.child.keys[0] == "l.g"
    assert set(plan.child.keys) == {"l.g", "l.k"}
    P.annotate_orders(plan)
    assert plan.child.delivered_ord == plan.child.keys
    assert plan.required_ord == ("l.g",)


def test_push_order_fuses_join_agg():
    join = P.JoinP(_scan_plan("L", "l"), _scan_plan("R", "r"),
                   ("l.k",), ("r.k",))
    plan = P.push_order(P.SumAggP(join, keys=("l.g", "l.k"),
                                  vals=("l.v",)))
    assert isinstance(plan, P.FusedJoinAggP)
    assert P.delivered_order(plan) == ("l.g", "l.k")


def test_fused_join_agg_plan_executes_with_one_sort():
    left, rows = _mk_left()
    right = _mk_right()
    env = {"L": left, "R": right}
    join = P.JoinP(_scan_plan("L", "l"), _scan_plan("R", "r"),
                   ("l.k",), ("r.k",))
    plan = P.push_order(P.SumAggP(join, keys=("l.g", "l.k"),
                                  vals=("l.v", "r.w")))
    assert isinstance(plan, P.FusedJoinAggP)
    out = P.eval_plan(plan, env)
    assert X.SORT_STATS.get("lexsort", 0) == 1
    want = {}
    wmap = {i: float(i * 10) for i in range(right.capacity)}
    for r in rows:
        if r["k"] in wmap:
            key = (r["g"], r["k"])
            v, w = want.get(key, (0.0, 0.0))
            want[key] = (v + r["v"], w + wmap[r["k"]])
    got = {(r["l.g"], r["l.k"]): (r["l.v"], r["r.w"])
           for r in out.to_rows()}
    assert got == want


def test_scan_memo_shares_build_cache_across_assignments():
    left, _ = _mk_left()
    right = _mk_right()
    env = {"L": left, "R": right}
    join = P.JoinP(_scan_plan("L", "l"), _scan_plan("R", "r"),
                   ("l.k",), ("r.k",))
    P.eval_plan(join, env)
    P.eval_plan(join, env)   # second assignment scanning the same dict
    assert X.SORT_STATS.get("build_argsort", 0) == 1
    assert X.SORT_STATS.get("build_reuse", 0) == 1


# -- general_join overflow accounting ----------------------------------------

def _overflow_case(n_left, dup, cap):
    left = FlatBag.from_rows([{"k": i % 3, "v": float(i)}
                              for i in range(n_left)],
                             {"k": "int", "v": "real"})
    right = FlatBag.from_rows([{"k": i % 3, "w": float(i)}
                               for i in range(dup * 3)],
                              {"k": "int", "w": "real"})
    return X.general_join(left, right, ("k",), ("k",), cap)


@pytest.mark.parametrize("use_kernel", [False, True])
def test_general_join_overflow_exact_count(use_kernel):
    n_left, dup = 9, 4     # every left row matches `dup` right rows
    total = n_left * dup
    for cap in (total, total - 1, total - 7, 1):
        left = FlatBag.from_rows([{"k": i % 3, "v": float(i)}
                                  for i in range(n_left)],
                                 {"k": "int", "v": "real"})
        right = FlatBag.from_rows([{"k": i % 3, "w": float(i)}
                                   for i in range(dup * 3)],
                                  {"k": "int", "w": "real"})
        out, overflow = X.general_join(left, right, ("k",), ("k",), cap,
                                       use_kernel=use_kernel)
        assert int(overflow) == max(total - cap, 0)
        assert int(out.count()) == min(total, cap)


def test_general_join_left_outer_counts_unmatched_rows():
    left = FlatBag.from_rows([{"k": i, "v": float(i)} for i in range(6)],
                             {"k": "int", "v": "real"})
    right = FlatBag.from_rows([{"k": 0, "w": 1.0}, {"k": 0, "w": 2.0}],
                              {"k": "int", "w": "real"})
    # k=0 matches twice, k=1..5 unmatched -> 1 row each: total 7
    out, overflow = X.general_join(left, right, ("k",), ("k",), 5,
                                   how="left_outer")
    assert int(overflow) == 2
    assert int(out.count()) == 5
    out2, ov2 = X.general_join(left, right, ("k",), ("k",), 16,
                               how="left_outer")
    assert int(ov2) == 0
    rows = out2.to_rows()
    assert sum(1 for r in rows if not r["__matched"]) == 5
    assert sum(1 for r in rows if r["__matched"]) == 2


def test_general_join_all_invalid_left():
    left = FlatBag.from_rows([], {"k": "int", "v": "real"}, capacity=4)
    right = _mk_right()
    out, overflow = X.general_join(left, right, ("k",), ("k",), 8)
    assert int(overflow) == 0
    assert int(out.count()) == 0


# -- distributed: key caches survive the exchange -----------------------------

def test_dist_join_reuses_shipped_keys():
    from repro.exec.dist import device_mesh_1d, run_distributed
    bag, rows = _mk_left(16)
    right = _mk_right(8)
    mesh = device_mesh_1d(1)

    def fn(env, ctx):
        X.reset_sort_stats()
        out = ctx.join(env["L"], env["R"], ("k",), ("k",))
        # both exchanges pack once and ship the packed key with the
        # rows, so the local join's probe pack AND build pack are cache
        # hits on the receiving side
        assert X.SORT_STATS.get("key_reuse", 0) >= 2, X.SORT_STATS
        return {"out": out}

    out, _ = run_distributed(fn, {"L": bag, "R": right}, mesh, jit=False)
    got = sorted((r["k"], r["v"], r["w"]) for r in out["out"].to_rows())
    want = sorted((r["k"], r["v"], float(r["k"] * 10)) for r in rows)
    assert got == want
