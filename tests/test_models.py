"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step on CPU, asserting output shapes and no NaNs."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config, get_smoke
from repro.models import transformer as T
from repro.train import optim as O
from repro.train.train_loop import make_train_step


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


def _batch(cfg, rng, B=2, S=16):
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.n_image_tokens:
        batch["embeds_prefix"] = jnp.zeros(
            (B, cfg.n_image_tokens, cfg.d_model), jnp.float32)
    if cfg.enc_layers:
        batch["enc_embeds"] = jax.random.normal(rng, (B, 24, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_loss(arch, rng):
    cfg = get_smoke(arch)
    params = T.init_params(cfg, rng)
    batch = _batch(cfg, rng)
    h = T.forward(cfg, params, batch["tokens"],
                  embeds_prefix=batch.get("embeds_prefix"),
                  enc_embeds=batch.get("enc_embeds"))
    S_out = 16 + (cfg.n_image_tokens or 0)
    assert h.shape == (2, S_out, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(h.astype(jnp.float32))))
    loss = T.loss_fn(cfg, params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch, rng):
    cfg = get_smoke(arch)
    params = T.init_params(cfg, rng)
    ocfg = O.OptConfig(kind="adamw", lr=1e-3, warmup=1, total_steps=10)
    step = make_train_step(cfg, ocfg)
    state = O.init_state(ocfg, params)
    batch = _batch(cfg, rng)
    l0 = T.loss_fn(cfg, params, batch)
    p1, s1, m1 = step(params, state, batch)
    assert bool(jnp.isfinite(m1["loss"]))
    # one more step on the same batch should reduce the loss
    p2, s2, m2 = step(p1, s1, batch)
    assert float(m2["loss"]) < float(l0) + 1e-3
    assert int(s2["step"]) == 2


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode(arch, rng):
    cfg = get_smoke(arch)
    params = T.init_params(cfg, rng)
    B, maxlen = 2, 32
    caches = T.init_cache(cfg, B, maxlen)
    enc_out = (jnp.zeros((B, 8, cfg.d_model), jnp.bfloat16)
               if cfg.enc_layers else None)
    token = jnp.zeros((B,), jnp.int32)
    logits, caches2 = T.decode_step(cfg, params, caches, token,
                                    jnp.asarray(3, jnp.int32),
                                    enc_out=enc_out)
    assert logits.shape == (B, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    # cache structure preserved
    assert jax.tree.structure(caches) == jax.tree.structure(caches2)


def test_decode_matches_forward_gqa():
    """Incremental decode equals teacher-forced forward logits for a
    full-attention arch (the KV-cache correctness property)."""
    cfg = get_smoke("deepseek_67b")
    rng = jax.random.PRNGKey(1)
    params = T.init_params(cfg, rng)
    B, S = 1, 8
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab)
    # teacher-forced logits at the last position
    h = T.forward(cfg, params, tokens)
    head = params["embed"] if cfg.tie_embeddings else params["head"]
    want = (h[:, -1].astype(jnp.float32) @ head.T.astype(jnp.float32))
    # incremental decode
    caches = T.init_cache(cfg, B, S)
    for t in range(S):
        logits, caches = T.decode_step(cfg, params, caches, tokens[:, t],
                                       jnp.asarray(t, jnp.int32))
    np.testing.assert_allclose(np.asarray(logits), np.asarray(want),
                               atol=0.35, rtol=0.15)  # bf16 accumulation


def test_full_configs_match_assignment():
    """The full (published) configs carry the exact assigned dims."""
    c = get_config("deepseek_67b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (95, 8192, 64, 8, 22016, 102400)
    c = get_config("arctic_480b")
    assert c.moe.num_experts == 128 and c.moe.top_k == 2
    assert c.moe.dense_residual
    c = get_config("gemma2_27b")
    assert c.attn_softcap == 50.0 and c.window == 4096
    assert c.n_layers == 46 and c.period == 2
    c = get_config("jamba_v0_1_52b")
    assert c.pattern.count("attn") == 1 and len(c.pattern) == 8
    assert c.moe.num_experts == 16
    c = get_config("mixtral_8x22b")
    assert c.moe.num_experts == 8 and c.window == 4096
    c = get_config("rwkv6_7b")
    assert c.pattern == ("rwkv",) and c.vocab == 65536
    c = get_config("whisper_base")
    assert c.enc_layers == 6 and c.cross_attention
    c = get_config("internvl2_1b")
    assert c.vocab == 151655 and c.n_kv_heads == 2


def test_param_counts_plausible():
    """Total parameter counts are in the right ballpark for the names."""
    import numpy as np

    def count(arch):
        cfg = get_config(arch)
        ab = T.abstract_params(cfg)
        return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(ab))

    assert 6.0e9 < count("rwkv6_7b") < 9.5e9
    assert 13e9 < count("nemotron_4_15b") < 18e9
    assert 60e9 < count("deepseek_67b") < 75e9
    assert 7.5e9 < count("gemma_7b") < 10e9
    assert 24e9 < count("gemma2_27b") < 32e9
    assert 120e9 < count("mixtral_8x22b") < 160e9
    assert 400e9 < count("arctic_480b") < 550e9
    assert 45e9 < count("jamba_v0_1_52b") < 60e9
    # internvl2-1b: the "1B" includes the InternViT tower, which is a
    # STUB per the assignment — the LM backbone alone is ~0.5B
    assert 0.4e9 < count("internvl2_1b") < 1.0e9
    assert 0.04e9 < count("whisper_base") < 0.15e9
