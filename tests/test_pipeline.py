"""LM token pipeline batching: the streaming-ingest-from-disk path
produces bit-for-bit identical batches to the in-memory generator path
(ISSUE 4 satellite — data/pipeline.py coverage)."""

import numpy as np
import pytest

from repro.data.generators import CORPUS_TYPES, gen_corpus
from repro.data.pipeline import TokenPipeline
from repro.storage import StorageCatalog


@pytest.fixture(scope="module")
def corpus():
    return gen_corpus(n_docs=24, seed=3)


@pytest.fixture(scope="module")
def stored_corpus(corpus, tmp_path_factory):
    """Stream the corpus to disk in four incremental batches."""
    cat = StorageCatalog(str(tmp_path_factory.mktemp("corpus_store")))
    w = cat.writer("corpus", CORPUS_TYPES, chunk_rows=64)
    docs = corpus["Corpus"]
    w.append({"Corpus": docs[:6], "LangScore": corpus["LangScore"]})
    for i in range(6, len(docs), 6):
        w.append({"Corpus": docs[i:i + 6]})
    return cat.open("corpus")


def test_stream_identical(corpus, stored_corpus):
    mem = TokenPipeline(batch=4, seq_len=32).build(corpus)
    disk = TokenPipeline(batch=4, seq_len=32).build_from_storage(
        stored_corpus)
    assert mem.stream.dtype == disk.stream.dtype
    assert np.array_equal(mem.stream, disk.stream)


def test_batches_bit_for_bit(corpus, stored_corpus):
    mem = TokenPipeline(batch=2, seq_len=16).build(corpus)
    disk = TokenPipeline(batch=2, seq_len=16).build_from_storage(
        stored_corpus)
    it_mem, it_disk = iter(mem), iter(disk)
    for _ in range(5):
        a, b = next(it_mem), next(it_disk)
        assert np.array_equal(np.asarray(a["tokens"]),
                              np.asarray(b["tokens"]))
        assert np.array_equal(np.asarray(a["labels"]),
                              np.asarray(b["labels"]))
    # deterministic addressing agrees too (checkpoint/resume contract)
    for cursor in (0, 3, 11):
        a, b = mem.batch_at(cursor), disk.batch_at(cursor)
        assert np.array_equal(np.asarray(a["tokens"]),
                              np.asarray(b["tokens"]))
        assert np.array_equal(np.asarray(a["labels"]),
                              np.asarray(b["labels"]))


def test_iter_wraps_consistently(corpus, stored_corpus):
    """Short stream + large batch forces the tiling path on both."""
    mem = TokenPipeline(batch=8, seq_len=64).build(corpus)
    disk = TokenPipeline(batch=8, seq_len=64).build_from_storage(
        stored_corpus)
    a, b = next(iter(mem)), next(iter(disk))
    assert np.array_equal(np.asarray(a["tokens"]),
                          np.asarray(b["tokens"]))
