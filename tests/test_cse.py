"""Cross-assignment CSE + whole-program compilation: a join subplan
shared by TOP + two dictionary assignments of one bundle evaluates
exactly once (counter-asserted via plans.EVAL_STATS), with
interpreter-vs-compiled parity on the nested outputs, through both the
eager scheduler (run_flat_program) and the single-jit executable."""

import numpy as np
import pytest

from repro.core import codegen as CG
from repro.core import interpreter as I
from repro.core import materialization as M
from repro.core import nrc as N
from repro.core import plans as P
from repro.core.unnesting import Catalog

PART_T = N.bag(N.tuple_t(pid=N.INT, pname=N.INT, price=N.REAL))
ORD_T = N.bag(N.tuple_t(odate=N.INT,
                        oparts=N.bag(N.tuple_t(pid=N.INT, qty=N.REAL))))
INPUT_TYPES = {"Ord": ORD_T, "Part": PART_T}
CATALOG = Catalog(unique_keys={"Part__F": ("pid",)})


def shared_join_query():
    """TOP + two dictionaries; both dictionaries materialize from the
    SAME oparts-Part join (one aggregated, one plain), which domain
    elimination turns into two assignments containing structurally
    identical join subplans (differing only in generated alias names)."""
    Part = N.Var("Part", PART_T)
    Ord = N.Var("Ord", ORD_T)

    def joined(x, mk):
        return N.for_in("op", x.oparts, lambda op:
            N.for_in("p", Part, lambda p:
                N.IfThen(op.pid.eq(p.pid), N.Singleton(mk(op, p)))))

    def tops(x):
        inner = joined(x, lambda op, p: N.record(pname=p.pname,
                                                 total=op.qty * p.price))
        return N.SumBy(inner, keys=("pname",), values=("total",))

    def lines(x):
        return joined(x, lambda op, p: N.record(pname=p.pname,
                                                qty=op.qty))

    return N.for_in("x", Ord, lambda x: N.Singleton(N.record(
        odate=x.odate, tops=tops(x), lines=lines(x))))


def gen_data(n_orders=12, seed=0):
    rng = np.random.RandomState(seed)
    orders = [{"odate": 20200000 + i,
               "oparts": [{"pid": int(rng.randint(1, 10)),
                           "qty": float(rng.randint(1, 5))}
                          for _ in range(rng.randint(0, 5))]}
              for i in range(n_orders)]
    parts = [{"pid": i, "pname": 100 + i,
              "price": float(rng.randint(1, 20))}
             for i in range(1, 11)]
    return {"Ord": orders, "Part": parts}


@pytest.fixture(scope="module")
def bundle():
    q = shared_join_query()
    prog = N.Program([N.Assignment("Q", q)])
    sp = M.shred_program(prog, INPUT_TYPES, domain_elimination=True)
    return q, sp


@pytest.fixture(scope="module")
def data():
    return gen_data()


def _nested_rows(sp, env, q):
    man = sp.manifests["Q"]
    parts = {(): env[man.top]}
    for path, name in man.dicts.items():
        parts[path] = env[name]
    return CG.parts_to_rows(parts, q.ty)


def test_shared_join_evaluates_once(bundle, data):
    q, sp = bundle
    cp = CG.compile_program(sp, CATALOG)
    # the bundle has TOP + 2 dictionary assignments, and CSE extracted
    # a shared node for the join both dictionaries contain
    names = [n for n, _ in cp.plans]
    assert any(n.startswith("__s") for n in names), cp.pretty()
    man = sp.manifests["Q"]
    assert man.top in names and len(man.dicts) == 2

    env = CG.columnar_shred_inputs(data, INPUT_TYPES)
    P.reset_eval_stats()
    out = CG.run_flat_program(cp, env)
    assert P.EVAL_STATS.get("join", 0) == 1, P.EVAL_STATS
    assert P.EVAL_STATS.get("ref", 0) == 2, P.EVAL_STATS

    # without CSE the same join executes once per dictionary
    cp2 = CG.compile_program(sp, CATALOG, cse=False)
    env2 = CG.columnar_shred_inputs(data, INPUT_TYPES)
    P.reset_eval_stats()
    out2 = CG.run_flat_program(cp2, env2)
    assert P.EVAL_STATS.get("join", 0) == 2, P.EVAL_STATS

    # CSE on/off agree with each other and with the oracle
    direct = I.eval_expr(q, data)
    assert I.bags_equal(direct, _nested_rows(sp, out, q))
    assert I.bags_equal(direct, _nested_rows(sp, out2, q))


def test_jit_program_matches_eager(bundle, data):
    """Compiled single-jit executable == eager scheduler, bit-for-bit,
    and warm re-invocation does not retrace."""
    q, sp = bundle
    cp = CG.compile_program(sp, CATALOG)
    env = CG.columnar_shred_inputs(data, INPUT_TYPES)
    eager = CG.run_flat_program(cp, dict(env))

    CG.reset_trace_stats()
    exe = CG.jit_program(cp)
    out = exe(env)
    assert CG.TRACE_STATS.get("traces") == 1
    for name in out:
        a, b = out[name], eager[name]
        assert np.array_equal(np.asarray(a.valid), np.asarray(b.valid))
        for c in b.data:
            assert np.array_equal(np.asarray(a.data[c]),
                                  np.asarray(b.data[c])), (name, c)
    # warm call: same executable, zero retrace
    exe(env)
    assert CG.TRACE_STATS.get("traces") == 1


def test_shared_node_scheduled_before_uses(bundle):
    _, sp = bundle
    cp = CG.compile_program(sp, CATALOG)
    pos = {n: i for i, (n, _) in enumerate(cp.plans)}
    for nd in cp.graph.nodes:
        for d in nd.deps:
            if d in pos:
                assert pos[d] < pos[nd.name], (d, nd.name)


def test_dce_drops_unconsumed_pipeline_stage(data):
    """A pipeline whose first query nobody reads is dead when outputs
    are narrowed to the final manifest."""
    Ord = N.Var("Ord", ORD_T)
    q1 = N.for_in("x", Ord, lambda x: N.Singleton(N.record(
        odate=x.odate, oparts=x.oparts)))
    q2 = N.SumBy(
        N.for_in("x", Ord, lambda x:
            N.for_in("op", x.oparts, lambda op:
                N.Singleton(N.record(odate=x.odate, qty=op.qty)))),
        keys=("odate",), values=("qty",))
    prog = N.Program([N.Assignment("Q1", q1), N.Assignment("Q2", q2)])
    sp = M.shred_program(prog, INPUT_TYPES, domain_elimination=True)
    man2 = sp.manifests["Q2"]
    outputs = tuple([man2.top] + list(man2.dicts.values()))
    cp = CG.compile_program(sp, CATALOG, outputs=outputs)
    names = [n for n, _ in cp.plans]
    assert "Q1" not in names, names
    # and the narrowed program still runs + matches the oracle
    env = CG.columnar_shred_inputs(data, INPUT_TYPES)
    out = CG.run_flat_program(cp, env)
    want = I.eval_expr(q2, data)
    got = out[man2.top].to_rows()
    assert I.bags_equal(want, got)


def test_program_level_column_pruning(data):
    """An intermediate assignment consumed only through a narrow scan
    drops the columns nobody reads."""
    q = shared_join_query()
    prog = N.Program([N.Assignment("Q", q)])
    sp = M.shred_program(prog, INPUT_TYPES, domain_elimination=True)
    man = sp.manifests["Q"]
    # consume only the top bag: the dictionaries die entirely
    cp = CG.compile_program(sp, CATALOG, outputs=(man.top,))
    names = [n for n, _ in cp.plans]
    assert names == [man.top], names


def test_param_in_plan_evaluates_with_bindings(data):
    """N.Param flows through shredding + compilation and binds at
    execution time (ExecSettings.params / executable params)."""
    Part = N.Var("Part", PART_T)
    Ord = N.Var("Ord", ORD_T)
    th = N.Param("th", N.REAL, default=5.0)

    def tops(x):
        inner = N.for_in("op", x.oparts, lambda op:
            N.for_in("p", Part, lambda p:
                N.IfThen(N.BoolOp("&&", op.pid.eq(p.pid),
                                  p.price.ge(th)),
                         N.Singleton(N.record(pname=p.pname,
                                              total=op.qty * p.price)))))
        return N.SumBy(inner, keys=("pname",), values=("total",))

    q = N.for_in("x", Ord, lambda x: N.Singleton(N.record(
        odate=x.odate, tops=tops(x))))
    prog = N.Program([N.Assignment("Q", q)])
    sp = M.shred_program(prog, INPUT_TYPES, domain_elimination=True)
    cp = CG.compile_program(sp, CATALOG)
    exe = CG.jit_program(cp)
    assert exe.param_defaults == {"th": 5.0}
    env = CG.columnar_shred_inputs(data, INPUT_TYPES)

    for val in (3.0, 12.0):
        out = exe(env, {"th": val})
        man = sp.manifests["Q"]
        parts = {(): out[man.top]}
        for path, name in man.dicts.items():
            parts[path] = out[name]
        got = CG.parts_to_rows(parts, q.ty)
        want = I.eval_expr(q, dict(data, __params__={"th": val}))
        assert I.bags_equal(want, got), val
    # both bindings ran through ONE trace
    assert CG.TRACE_STATS.get("traces", 0) >= 1
    # a misspelled parameter name is a caller error, not a silent
    # fall-back to the default value
    with pytest.raises(AssertionError, match="unknown parameter"):
        exe(env, {"thresh": 3.0})


def test_lift_plan_parameters(bundle, data):
    """Plan-level constant lifting: literals become bindable Params,
    defaults reproduce the original results."""
    Ord = N.Var("Ord", ORD_T)
    q = N.SumBy(
        N.for_in("x", Ord, lambda x:
            N.for_in("op", x.oparts, lambda op:
                N.IfThen(op.qty.ge(N.Const(2.0, N.REAL)),
                         N.Singleton(N.record(odate=x.odate,
                                              qty=op.qty))))),
        keys=("odate",), values=("qty",))
    prog = N.Program([N.Assignment("Q", q)])
    sp = M.shred_program(prog, INPUT_TYPES, domain_elimination=True)
    cp = CG.compile_program(sp, CATALOG)
    defaults = P.lift_plan_parameters(cp.graph)
    assert list(defaults.values()) == [2.0]
    exe = CG.jit_program(cp)
    assert exe.param_defaults == defaults
    env = CG.columnar_shred_inputs(data, INPUT_TYPES)
    out = exe(env)                       # defaults == original constants
    want = I.eval_expr(q, data)
    assert I.bags_equal(want, out[sp.manifests["Q"].top].to_rows())
    # rebind: lowering the threshold must change the result
    (name,) = defaults
    out2 = exe(env, {name: 0.0})
    want2 = I.eval_expr(N.Program([prog.assignments[0]]).assignments[0]
                        .expr, data)
    total = sum(r["qty"] for r in out2[sp.manifests["Q"].top].to_rows())
    assert total >= sum(r["qty"] for r in want2)


def test_schema_of_names_offender():
    bad = N.tuple_t(a=N.INT, b=N.bag(N.tuple_t(c=N.INT)))
    with pytest.raises(TypeError) as ei:
        CG.schema_of(bad, where="assignment Q__D_x")
    msg = str(ei.value)
    assert "'b'" in msg and "assignment Q__D_x" in msg
    assert "shredded" in msg
